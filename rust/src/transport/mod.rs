//! Non-blocking ring transport: double-buffered links that overlap tile
//! transfer with GEMM inside a layer (paper §III-D made real).
//!
//! Until this subsystem existed, every ring tile moved over a blocking
//! `std::sync::mpsc` send/recv serialized against PJRT dispatch on the
//! receiving worker — within a layer nothing actually overlapped and the
//! modeled `hidden_comm_s` was fiction on the real path. The transport
//! fixes that with one abstraction and two implementations:
//!
//! * [`RingLink`] — one *directed* ring-link endpoint. A worker holds the
//!   send endpoint toward its successor and the receive endpoint from its
//!   predecessor. `post_send` hands a tile to the link and returns
//!   immediately; `try_recv` observes arrival without consuming;
//!   `complete_recv` consumes (blocking only if the tile has not arrived
//!   yet — and *that* blocked time is the measured exposed communication).
//! * [`threaded_pair`] / [`threaded_ring`] — the real fabric: a dedicated
//!   io-thread per link drains the send slots, so the tile transfer
//!   proceeds while the receiver's PJRT GEMM runs.
//! * [`mem_link_pair`] / [`mem_ring`] — the in-process twin used by the
//!   lockstep collective helpers and the property tests, with the same
//!   slot/backpressure contract but instant delivery (modeled time lives
//!   in [`crate::sim::net::LinkModel`], the simulator's matching model).
//!
//! # Slot / backpressure contract
//!
//! Every link double-buffers: at most [`LINK_SLOTS`] tiles may be in
//! flight (posted but not yet taken off the wire — a tile parked in the
//! receive endpoint's pending slot by `try_recv` counts as taken).
//! Posting the third tile *backpressures* — the threaded link blocks the
//! poster until the receiver takes one, the in-process link returns a
//! `Fabric` error (a single-threaded lockstep has nobody left to drain
//! the slot, so blocking would be a deadlock). Two slots are exactly what the
//! bulk-synchronous ring walks need: the lockstep schedules keep
//! neighbor skew at one step, so one tile can still be in flight from
//! step *s* while step *s+1*'s tile is already posted — and what layer-
//! granular request interleaving needs: two requests' tiles share a
//! link's slots without ever queueing a third.
//!
//! # Transport order
//!
//! [`RingIo::ag_walk`] / [`RingIo::rs_walk`] are the one implementation
//! of the AG⊕GEMM / GEMM⊕RS step walks (paper Fig. 6/7), used verbatim
//! by the cluster workers: on every step the tile is **posted before the
//! entry/exit GEMM runs** and reaped only after it returns, so the wire
//! and the PJRT dispatch genuinely overlap. The transport-order unit
//! test below pins that ordering.
//!
//! [`RingIo::ag_walk_micro`] / [`RingIo::rs_walk_micro`] are the
//! planner-grain refinements: each device's SP tile splits into
//! `T/d` micro-tiles (row slices) and the walk posts **one micro-tile
//! per sub-step**, so a micro-tile's transfer overlaps the previous
//! micro-tile's wire time *within* a ring step and the exposed tail of
//! each phase shrinks from one tile transfer to one micro transfer.
//! The GEMM stays tile-granular (the AOT PJRT artifacts exist only at
//! manifest tile shapes), firing at each tile's first sub-step. Because
//! every sub-step still pairs one post with one blocking consume, the
//! lockstep skew stays at one sub-step and the slot bound is unchanged:
//! backpressure triggers at [`LINK_SLOTS`] regardless of the grain `T`
//! (the loom micro-walk model pins this). Per phase the walk moves the
//! same total rows as the coarse walk — ring bytes and sync points are
//! grain-invariant, parity pinned by the collective and engine tests.
//!
//! # Exposed vs hidden accounting
//!
//! Each tile carries its transfer-start instant (stamped by the
//! io-thread at wire pickup, so sender-side dwell is never counted
//! twice). On consumption the receive endpoint splits the tile's
//! in-flight span into *exposed* seconds (time the consumer sat blocked
//! in `complete_recv`) and *hidden* seconds (span that elapsed while
//! the consumer was busy computing); send endpoints separately account
//! backpressure stalls as exposed. Workers attribute the per-layer
//! deltas to requests, and both engines report the totals through
//! [`crate::engine::InferOutcome`].
//!
//! # Wire format + pool lease contract
//!
//! Links move [`WireTile`]s, not raw tensors: every [`RingIo`] owns a
//! [`TileCodec`] that encodes on post and decodes on complete, so the
//! walks (and everything above them — workers, collectives, engines)
//! transparently move `elems × elem_bytes` wire bytes per tile under
//! the selected [`WireFormat`] (4/2/1 B/elem for f32/f16/i8). `RingIo`
//! byte counters always account the **encoded** size. F32 is exact and
//! zero-copy (the payload is a refcounted tensor — posting and
//! in-process forwarding never copy activation data); f16/i8 are lossy
//! (bounds in [`wire`]'s docs) and write into buffers leased from the
//! codec's [`TileBufPool`], which return to their origin pool when the
//! decoded tile drops — steady-state posting allocates nothing, pinned
//! by the no-alloc property test below and trended by the transport
//! bench's pool hit rate.
//!
//! # Model-checked concurrency
//!
//! Every thread, lock, channel and clock in this module comes from the
//! [`sync`] shim — `std`-backed normally, swapped for the vendored
//! `loom` model checker under `RUSTFLAGS="--cfg loom"` so
//! `tests/loom_transport.rs` can exhaustively explore the slot
//! protocol's schedules (no deadlock, no lost tile, backpressure
//! exactly at [`LINK_SLOTS`] — the catalogue lives in
//! `docs/INVARIANTS.md`). The `transport-sync-shim` lint rule keeps new
//! transport code from bypassing the shim.

use std::cell::RefCell;
use std::collections::VecDeque;
use std::rc::Rc;

use self::sync::time::{self, Instant};
use self::sync::{Arc, Receiver, Sender, TryRecvError};
use crate::error::{GalaxyError, Result};
use crate::parallel::overlap::{micro_rows, AgMicroStep, AgStep, RsMicroStep, RsStep};
use crate::tensor::Tensor2;

pub mod sync;
pub mod wire;

pub use wire::{PoolStats, TileBuf, TileBufPool, TileCodec, WireFormat, WireTile};

/// Tiles a link keeps in flight before backpressuring the poster: the
/// double-buffering of §III-D. The simulator's
/// [`crate::sim::net::LinkModel`] models the same bound.
pub const LINK_SLOTS: usize = 2;

/// Buffered slots in the io-thread's queue. The io-thread's in-hand tile
/// is the other slot, so the poster backpressures after exactly
/// [`LINK_SLOTS`] tiles in flight.
///
/// Under `--cfg galaxy_mutate_backpressure` this is deliberately
/// mutated to `LINK_SLOTS` (three tiles in flight) — a seeded bug whose
/// only purpose is proving the loom suite has teeth: the
/// `mutation_*` test in `tests/loom_transport.rs` must fail against it.
#[cfg(not(galaxy_mutate_backpressure))]
const SLOT_BUFFER: usize = LINK_SLOTS - 1;
#[cfg(galaxy_mutate_backpressure)]
const SLOT_BUFFER: usize = LINK_SLOTS;

/// Cumulative per-endpoint transfer accounting.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct LinkStats {
    /// Tiles this endpoint posted or consumed.
    pub tiles: u64,
    /// Seconds this endpoint stalled on the wire: blocked in
    /// `complete_recv` waiting for an arrival, or blocked in `post_send`
    /// under slot backpressure. This is the *exposed* communication.
    pub exposed_s: f64,
    /// Post-to-consumption seconds that did **not** stall the consumer —
    /// wire occupancy hidden behind the consumer's compute (receive
    /// endpoints only).
    pub hidden_s: f64,
}

/// One directed ring-link endpoint (see module docs for the contract).
///
/// A send endpoint answers only `post_send`; a receive endpoint answers
/// only `try_recv`/`complete_recv`; calling the wrong direction is a
/// `Fabric` error (never a silent no-op).
pub trait RingLink {
    /// Hand an encoded tile to the link. Returns as soon as the tile
    /// occupies a free slot; with [`LINK_SLOTS`] tiles already in flight
    /// the call backpressures (threaded: blocks; in-process: errors).
    fn post_send(&mut self, tile: WireTile) -> Result<()>;

    /// Non-blocking arrival check: polls the wire, parking an arrived
    /// tile in the endpoint's pending slot; returns whether a tile is
    /// ready for [`RingLink::complete_recv`].
    fn try_recv(&mut self) -> Result<bool>;

    /// Consume the next tile, blocking until it arrives. Blocked time is
    /// accounted as exposed communication.
    fn complete_recv(&mut self) -> Result<WireTile>;

    /// Cumulative transfer accounting for this endpoint.
    fn stats(&self) -> LinkStats;
}

/// A tile on the wire, stamped with the instant its transfer started
/// (re-stamped by the io-thread at wire pickup) so the receiver can
/// split the transfer into hidden and exposed seconds.
struct TileMsg {
    tile: WireTile,
    posted: Instant,
}

// ---------------------------------------------------------------------
// Threaded links (the real fabric)
// ---------------------------------------------------------------------

/// Send endpoint of a threaded link: a bounded slot queue drained by a
/// dedicated io-thread, so `post_send` returns while the transfer is
/// still in progress.
pub struct ThreadedTx {
    /// One buffered slot; the io-thread's in-hand tile is the second —
    /// together the link holds [`LINK_SLOTS`] tiles, and the next post
    /// blocks until the receiver consumes one.
    slots: Sender<TileMsg>,
    stats: LinkStats,
}

/// Receive endpoint of a threaded link.
pub struct ThreadedRx {
    wire: Receiver<TileMsg>,
    pending: Option<TileMsg>,
    stats: LinkStats,
}

/// Wire one threaded link: returns (send endpoint, receive endpoint) and
/// spawns the io-thread that moves tiles between them. The io-thread
/// exits when either endpoint drops, which is what unblocks the peer: a
/// worker failing mid-layer drops its endpoints, its neighbors' blocked
/// `post_send`/`complete_recv` calls return `Fabric` errors, and the
/// leader poisons the cluster instead of both neighbors deadlocking.
pub fn threaded_pair() -> Result<(ThreadedTx, ThreadedRx)> {
    let (slot_tx, slot_rx) = sync::sync_channel::<TileMsg>(SLOT_BUFFER);
    // Rendezvous wire: the io-thread's send completes only when the
    // receiver consumes, so "in flight" = slot + io-hand = LINK_SLOTS.
    let (wire_tx, wire_rx) = sync::sync_channel::<TileMsg>(0);
    sync::thread::spawn_named("galaxy-link-io", move || {
        while let Ok(mut msg) = slot_rx.recv() {
            // Re-stamp at wire pickup: sender-side dwell (slot queue,
            // backpressure blocking) is already accounted as the
            // sender's exposed time — stamping here keeps it out of
            // the receiver's hidden/exposed split, so no wall-clock
            // second is counted on both sides.
            msg.posted = time::now();
            if wire_tx.send(msg).is_err() {
                break; // receive endpoint gone
            }
        }
    })?;
    Ok((
        ThreadedTx { slots: slot_tx, stats: LinkStats::default() },
        ThreadedRx { wire: wire_rx, pending: None, stats: LinkStats::default() },
    ))
}

impl RingLink for ThreadedTx {
    fn post_send(&mut self, tile: WireTile) -> Result<()> {
        let t0 = time::now();
        self.slots
            .send(TileMsg { tile, posted: t0 })
            .map_err(|_| GalaxyError::Fabric("ring link down: receive endpoint dropped".into()))?;
        // Any time spent blocked here was slot backpressure: the wire was
        // the bottleneck, so it counts as exposed communication.
        self.stats.exposed_s += t0.elapsed().as_secs_f64();
        self.stats.tiles += 1;
        Ok(())
    }

    fn try_recv(&mut self) -> Result<bool> {
        Err(GalaxyError::Fabric("try_recv on a send endpoint".into()))
    }

    fn complete_recv(&mut self) -> Result<WireTile> {
        Err(GalaxyError::Fabric("complete_recv on a send endpoint".into()))
    }

    fn stats(&self) -> LinkStats {
        self.stats
    }
}

impl ThreadedRx {
    fn consume(&mut self, msg: TileMsg, blocked_s: f64) -> WireTile {
        let span_s = msg.posted.elapsed().as_secs_f64();
        self.stats.exposed_s += blocked_s;
        self.stats.hidden_s += (span_s - blocked_s).max(0.0);
        self.stats.tiles += 1;
        msg.tile
    }
}

impl RingLink for ThreadedRx {
    fn post_send(&mut self, _tile: WireTile) -> Result<()> {
        Err(GalaxyError::Fabric("post_send on a receive endpoint".into()))
    }

    fn try_recv(&mut self) -> Result<bool> {
        if self.pending.is_some() {
            return Ok(true);
        }
        match self.wire.try_recv() {
            Ok(msg) => {
                self.pending = Some(msg);
                Ok(true)
            }
            Err(TryRecvError::Empty) => Ok(false),
            Err(TryRecvError::Disconnected) => {
                Err(GalaxyError::Fabric("ring link down: send endpoint dropped".into()))
            }
        }
    }

    fn complete_recv(&mut self) -> Result<WireTile> {
        if let Some(msg) = self.pending.take() {
            // Arrived while the consumer was computing: fully hidden.
            return Ok(self.consume(msg, 0.0));
        }
        let waited = time::now();
        let msg = self
            .wire
            .recv()
            .map_err(|_| GalaxyError::Fabric("ring link down: send endpoint dropped".into()))?;
        let blocked_s = waited.elapsed().as_secs_f64();
        Ok(self.consume(msg, blocked_s))
    }

    fn stats(&self) -> LinkStats {
        self.stats
    }
}

// ---------------------------------------------------------------------
// In-process links (lockstep collectives, tests)
// ---------------------------------------------------------------------

/// In-process link endpoint: both halves share one bounded queue with
/// instant delivery. Where the threaded link would block, this one
/// errors — a single-threaded lockstep has no other thread left to make
/// progress, so a would-block *is* a deadlock and must surface. The
/// queue holds encoded [`WireTile`]s, so forwarding a transited F32
/// tile moves a refcount, never a data copy.
pub struct MemLink {
    queue: Rc<RefCell<VecDeque<WireTile>>>,
    capacity: usize,
    /// Send endpoints post; receive endpoints consume.
    sender: bool,
    stats: LinkStats,
}

/// Wire one in-process link with `capacity` slots: (send, receive).
pub fn mem_link_pair(capacity: usize) -> (MemLink, MemLink) {
    let queue = Rc::new(RefCell::new(VecDeque::new()));
    (
        MemLink { queue: queue.clone(), capacity, sender: true, stats: LinkStats::default() },
        MemLink { queue, capacity, sender: false, stats: LinkStats::default() },
    )
}

/// Pair each device's send endpoint with its predecessor's receive
/// endpoint: pair `i`'s receive half serves device `(i+1) % d`, so
/// rotating the receive column right by one lines the ring up — the one
/// place the ring rotation lives.
fn rotate_ring<T, R>(txs: Vec<T>, mut rxs: Vec<R>) -> Vec<(T, R)> {
    rxs.rotate_right(1);
    txs.into_iter().zip(rxs).collect()
}

/// Wire `d` link pairs into a ring: element `i` of the result is device
/// `i`'s (send-to-`(i+1)%d`, receive-from-`(i-1)%d`) endpoint pair.
fn ring_of<T, R>(
    d: usize,
    mut pair: impl FnMut() -> Result<(T, R)>,
) -> Result<Vec<(T, R)>> {
    let mut txs = Vec::with_capacity(d);
    let mut rxs = Vec::with_capacity(d);
    for _ in 0..d {
        let (tx, rx) = pair()?;
        txs.push(tx);
        rxs.push(rx);
    }
    Ok(rotate_ring(txs, rxs))
}

/// Wire a ring of `d` in-process links: element `i` is device `i`'s
/// (send-to-successor, receive-from-predecessor) endpoint pair.
pub fn mem_ring(d: usize, capacity: usize) -> Vec<(MemLink, MemLink)> {
    let (txs, rxs) = (0..d).map(|_| mem_link_pair(capacity)).unzip();
    rotate_ring(txs, rxs)
}

impl RingLink for MemLink {
    fn post_send(&mut self, tile: WireTile) -> Result<()> {
        if !self.sender {
            return Err(GalaxyError::Fabric("post_send on a receive endpoint".into()));
        }
        let mut q = self.queue.borrow_mut();
        if q.len() >= self.capacity {
            return Err(GalaxyError::Fabric(format!(
                "transport backpressure: {} tiles already in flight (single-threaded \
                 lockstep would deadlock on the third)",
                self.capacity
            )));
        }
        q.push_back(tile);
        self.stats.tiles += 1;
        Ok(())
    }

    fn try_recv(&mut self) -> Result<bool> {
        if self.sender {
            return Err(GalaxyError::Fabric("try_recv on a send endpoint".into()));
        }
        Ok(!self.queue.borrow().is_empty())
    }

    fn complete_recv(&mut self) -> Result<WireTile> {
        if self.sender {
            return Err(GalaxyError::Fabric("complete_recv on a send endpoint".into()));
        }
        let tile = self.queue.borrow_mut().pop_front().ok_or_else(|| {
            GalaxyError::Fabric(
                "complete_recv with no tile in flight: lockstep would deadlock".into(),
            )
        })?;
        self.stats.tiles += 1;
        Ok(tile)
    }

    fn stats(&self) -> LinkStats {
        self.stats
    }
}

// ---------------------------------------------------------------------
// Per-device ring I/O: the one implementation of the phase walks
// ---------------------------------------------------------------------

/// One device's view of the ring: its send endpoint toward the successor,
/// its receive endpoint from the predecessor, the codec that encodes
/// tiles for the wire, and the counters the cluster reports per request.
pub struct RingIo {
    pub next: Box<dyn RingLink + Send>,
    pub prev: Box<dyn RingLink + Send>,
    /// Encode-on-post / decode-on-complete for the walks.
    codec: TileCodec,
    /// **Encoded** bytes successfully posted — counted only **after**
    /// the link accepted the tile, so failure paths never overreport
    /// traffic, and quantized formats report their true wire volume.
    pub bytes: u64,
    /// Ring synchronization phases walked.
    pub sync_points: u64,
}

impl RingIo {
    pub fn new(next: Box<dyn RingLink + Send>, prev: Box<dyn RingLink + Send>) -> Self {
        Self::with_format(next, prev, WireFormat::F32)
    }

    /// Ring I/O encoding posts under `format`.
    pub fn with_format(
        next: Box<dyn RingLink + Send>,
        prev: Box<dyn RingLink + Send>,
        format: WireFormat,
    ) -> Self {
        Self { next, prev, codec: TileCodec::new(format), bytes: 0, sync_points: 0 }
    }

    /// The wire format this device encodes posts with.
    pub fn wire_format(&self) -> WireFormat {
        self.codec.format()
    }

    /// Encode-buffer pool accounting for this device's codec. Errors if
    /// a peer thread died while holding the pool lock (poison maps to
    /// [`GalaxyError::Fabric`], like a dead neighbor).
    pub fn pool_stats(&self) -> Result<PoolStats> {
        self.codec.pool_stats()
    }

    /// Combined endpoint accounting: exposed seconds from both sides
    /// (recv stalls + send backpressure), hidden from the receive side.
    pub fn link_stats(&self) -> LinkStats {
        let (tx, rx) = (self.next.stats(), self.prev.stats());
        LinkStats {
            tiles: tx.tiles + rx.tiles,
            exposed_s: tx.exposed_s + rx.exposed_s,
            hidden_s: rx.hidden_s,
        }
    }

    /// Ring-AllGather walk (paper Fig. 6): on every step, **post the
    /// held tile first**, run the overlapped entry GEMM on it while the
    /// transfer proceeds, then reap the predecessor's tile. `tiles` is
    /// the slot store with this device's own tile pre-placed; slots are
    /// refcounted, so posting and holding a tile never copy activation
    /// data. Returns the per-slot outputs of `compute` (None where
    /// nothing overlaps).
    pub fn ag_walk<T>(
        &mut self,
        steps: &[AgStep],
        tiles: &mut [Option<Arc<Tensor2>>],
        mut compute: impl FnMut(usize, &Tensor2) -> Result<Option<T>>,
    ) -> Result<Vec<Option<T>>> {
        let mut outs: Vec<Option<T>> = (0..tiles.len()).map(|_| None).collect();
        for step in steps {
            let slot = step.compute_tile;
            let xt = tiles[slot]
                .clone() // refcount bump, not a copy
                .ok_or_else(|| GalaxyError::Fabric(format!("AG: tile {slot} missing")))?;
            if step.send_tile.is_some() {
                let encoded = self.codec.encode(&xt)?;
                let bytes = encoded.wire_bytes();
                self.next.post_send(encoded)?;
                self.bytes += bytes;
            }
            outs[slot] = compute(slot, xt.as_ref())?;
            if let Some(r) = step.recv_tile {
                tiles[r] = Some(self.prev.complete_recv()?.decode()?);
            }
        }
        Ok(outs)
    }

    /// Micro-grain Ring-AllGather walk: the planned refinement of
    /// [`RingIo::ag_walk`]. The wire moves `grain/d` row-sliced
    /// micro-tiles per ring step; the entry GEMM still runs once per
    /// whole tile (at the tile's first sub-step — AOT artifacts only
    /// exist at tile shapes). Received micro-slices are reassembled into
    /// whole tiles, so at f32 the gathered slots are bit-identical to
    /// the coarse walk's. With `grain == tiles.len()` this degenerates
    /// to exactly one post per step, the coarse schedule.
    pub fn ag_walk_micro<T>(
        &mut self,
        steps: &[AgMicroStep],
        grain: usize,
        tiles: &mut [Option<Arc<Tensor2>>],
        mut compute: impl FnMut(usize, &Tensor2) -> Result<Option<T>>,
    ) -> Result<Vec<Option<T>>> {
        let per = micro_split_arity(tiles.len(), grain)?;
        let mut outs: Vec<Option<T>> = (0..tiles.len()).map(|_| None).collect();
        // Arrival order is the schedule order, and a coarse step receives
        // all of one tile's micros before the next step starts — one
        // inbox reassembles every transited tile in turn.
        let mut inbox: Vec<Arc<Tensor2>> = Vec::with_capacity(per);
        for step in steps {
            let slot = step.compute.tile;
            let xt = tiles[slot]
                .clone() // refcount bump, not a copy
                .ok_or_else(|| GalaxyError::Fabric(format!("AG: tile {slot} missing")))?;
            if let Some(send) = step.send {
                let micro = Arc::new(slice_micro(&xt, per, send.micro)?);
                let encoded = self.codec.encode(&micro)?;
                let bytes = encoded.wire_bytes();
                self.next.post_send(encoded)?;
                self.bytes += bytes;
            }
            if step.compute.micro == 0 {
                outs[slot] = compute(slot, xt.as_ref())?;
            }
            if let Some(recv) = step.recv {
                inbox.push(self.prev.complete_recv()?.decode()?);
                if recv.micro + 1 == per {
                    let parts: Vec<Tensor2> = inbox.drain(..).map(take_tile).collect();
                    tiles[recv.tile] = Some(Arc::new(Tensor2::concat_rows(&parts)?));
                }
            }
        }
        Ok(outs)
    }

    /// Micro-grain Ring-ReduceScatter walk: the planned refinement of
    /// [`RingIo::rs_walk`]. The previous step's accumulation is forwarded
    /// one row-sliced micro-tile per sub-step; the exit GEMM still runs
    /// once per whole tile, and arriving micro partials reduce-add into
    /// their row range of the running tile. Per element the addition
    /// chain is hop-for-hop the coarse walk's, so the reduced tile is
    /// bit-identical at f32.
    pub fn rs_walk_micro(
        &mut self,
        steps: &[RsMicroStep],
        grain: usize,
        mut partial: impl FnMut(usize) -> Result<Tensor2>,
    ) -> Result<Tensor2> {
        // The compute refs cover every tile index exactly `per` times,
        // so the ring size is the largest index + 1.
        let d = steps
            .iter()
            .map(|s| s.compute.tile + 1)
            .max()
            .ok_or_else(|| GalaxyError::Fabric("RS: empty schedule".into()))?;
        let per = micro_split_arity(d, grain)?;
        let mut acc: Option<Arc<Tensor2>> = None;
        let mut cur: Option<Tensor2> = None;
        for step in steps {
            if let Some(send) = step.send {
                let t = acc.as_ref().ok_or_else(|| {
                    GalaxyError::Fabric("RS: nothing accumulated to send".into())
                })?;
                let micro = Arc::new(slice_micro(t, per, send.micro)?);
                let encoded = self.codec.encode(&micro)?;
                let bytes = encoded.wire_bytes();
                self.next.post_send(encoded)?;
                self.bytes += bytes;
                if send.micro + 1 == per {
                    acc = None; // fully forwarded
                }
            }
            if step.compute.micro == 0 {
                cur = Some(partial(step.compute.tile)?);
            }
            if let Some(recv) = step.recv {
                let got = self.prev.complete_recv()?.decode()?;
                let o = cur.as_mut().ok_or_else(|| {
                    GalaxyError::Fabric("RS: micro partial arrived before its tile".into())
                })?;
                let off = micro_split_offset(o.rows(), per, recv.micro)?;
                o.add_assign_rows(off, &got)?;
            }
            if step.compute.micro + 1 == per {
                let done = cur.take().ok_or_else(|| {
                    GalaxyError::Fabric("RS: micro schedule finished a tile it never started".into())
                })?;
                acc = Some(Arc::new(done));
            }
        }
        let acc = acc.ok_or_else(|| GalaxyError::Fabric("RS: empty schedule".into()))?;
        // The final accumulation was never posted, so the Arc is unique;
        // the clone fallback only guards exotic custom links.
        Ok(Arc::try_unwrap(acc).unwrap_or_else(|a| (*a).clone()))
    }

    /// Ring-ReduceScatter walk (paper Fig. 7): **forward the previous
    /// step's accumulation first**, run the exit GEMM while it rides the
    /// ring, then reduce-add the partial arriving from the predecessor.
    /// Returns this device's fully reduced tile.
    pub fn rs_walk(
        &mut self,
        steps: &[RsStep],
        mut partial: impl FnMut(usize) -> Result<Tensor2>,
    ) -> Result<Tensor2> {
        let mut acc: Option<Arc<Tensor2>> = None;
        for step in steps {
            if step.send_tile.is_some() {
                let t = acc.take().ok_or_else(|| {
                    GalaxyError::Fabric("RS: nothing accumulated to send".into())
                })?;
                let encoded = self.codec.encode(&t)?;
                let bytes = encoded.wire_bytes();
                self.next.post_send(encoded)?;
                self.bytes += bytes;
            }
            let mut o = partial(step.compute_tile)?;
            if step.recv_tile.is_some() {
                o.add_assign(&self.prev.complete_recv()?.decode()?)?;
            }
            acc = Some(Arc::new(o));
        }
        let acc = acc.ok_or_else(|| GalaxyError::Fabric("RS: empty schedule".into()))?;
        // The final accumulation was never posted, so the Arc is unique;
        // the clone fallback only guards exotic custom links.
        Ok(Arc::try_unwrap(acc).unwrap_or_else(|a| (*a).clone()))
    }
}

/// Wire a ring of `d` threaded links: element `i` is device `i`'s
/// [`RingIo`] (sends to `(i+1)%d`, receives from `(i-1)%d`). Posts are
/// F32 (exact); use [`threaded_ring_with`] to quantize the wire.
pub fn threaded_ring(d: usize) -> Result<Vec<RingIo>> {
    threaded_ring_with(d, WireFormat::F32)
}

/// [`threaded_ring`] with every device encoding posts under `format`.
pub fn threaded_ring_with(d: usize, format: WireFormat) -> Result<Vec<RingIo>> {
    Ok(ring_of(d, threaded_pair)?
        .into_iter()
        .map(|(tx, rx)| RingIo::with_format(Box::new(tx), Box::new(rx), format))
        .collect())
}

/// Move a gathered slot tile out of its `Arc` (unique after a walk — the
/// only other holders were in-flight encodes, consumed by then; the
/// clone fallback covers a neighbor still holding our own tile's ref).
pub fn take_tile(tile: Arc<Tensor2>) -> Tensor2 {
    Arc::try_unwrap(tile).unwrap_or_else(|a| (*a).clone())
}

/// Fallible twin of [`crate::parallel::overlap::micro_per_tile`]: a
/// malformed grain arriving over the control plane is a `Fabric` error,
/// not a panic.
fn micro_split_arity(d: usize, grain: usize) -> Result<usize> {
    if d == 0 || grain < d || grain % d != 0 {
        return Err(GalaxyError::Fabric(format!(
            "micro walk: grain {grain} is not a positive multiple of the ring size {d}"
        )));
    }
    Ok(grain / d)
}

/// Row-slice micro-tile `micro` of `per` out of a tile (the split is
/// [`crate::parallel::overlap::micro_rows`], shared with the schedules
/// and the simulator so every layer agrees on the geometry).
fn slice_micro(tile: &Arc<Tensor2>, per: usize, micro: usize) -> Result<Tensor2> {
    let rows = checked_micro_rows(tile.rows(), per)?;
    let off: usize = rows[..micro].iter().sum();
    tile.slice_rows(off, rows[micro])
}

/// Row offset of micro-tile `micro` within its tile.
fn micro_split_offset(tile_rows: usize, per: usize, micro: usize) -> Result<usize> {
    let rows = checked_micro_rows(tile_rows, per)?;
    Ok(rows[..micro].iter().sum())
}

fn checked_micro_rows(tile_rows: usize, per: usize) -> Result<Vec<usize>> {
    if per == 0 || tile_rows < per {
        return Err(GalaxyError::Fabric(format!(
            "micro walk: cannot split a {tile_rows}-row tile into {per} micro-tiles"
        )));
    }
    Ok(micro_rows(tile_rows, per))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::collective::reference;
    use crate::parallel::overlap::{
        all_gather_micro_steps, all_gather_steps, reduce_scatter_micro_steps,
        reduce_scatter_steps,
    };
    use std::sync::atomic::{AtomicBool, Ordering};
    use std::sync::{Arc, Mutex};
    use std::time::Duration;

    fn tile(v: f32) -> Tensor2 {
        Tensor2::full(2, 3, v)
    }

    /// Recording endpoint for the transport-order test: logs every
    /// post/recv into a shared journal; receives from a pre-loaded queue.
    struct RecordingLink {
        journal: Arc<Mutex<Vec<String>>>,
        step: std::cell::Cell<usize>,
        incoming: VecDeque<Tensor2>,
        stats: LinkStats,
    }

    impl RecordingLink {
        fn new(journal: Arc<Mutex<Vec<String>>>, incoming: Vec<Tensor2>) -> Self {
            Self {
                journal,
                step: std::cell::Cell::new(0),
                incoming: incoming.into(),
                stats: LinkStats::default(),
            }
        }

        fn log(&self, what: &str) {
            self.journal.lock().unwrap().push(format!("{what}{}", self.step.get()));
            self.step.set(self.step.get() + 1);
        }
    }

    impl RingLink for RecordingLink {
        fn post_send(&mut self, _tile: WireTile) -> Result<()> {
            self.log("post");
            self.stats.tiles += 1;
            Ok(())
        }

        fn try_recv(&mut self) -> Result<bool> {
            Ok(!self.incoming.is_empty())
        }

        fn complete_recv(&mut self) -> Result<WireTile> {
            self.log("recv");
            self.incoming
                .pop_front()
                .map(WireTile::plain)
                .ok_or_else(|| GalaxyError::Fabric("recording link exhausted".into()))
        }

        fn stats(&self) -> LinkStats {
            self.stats
        }
    }

    /// The acceptance-criterion ordering: on every AG step with a send,
    /// `post_send` is issued *before* the entry GEMM and the receive is
    /// reaped *after* — the worker never blocks in recv while its GEMM
    /// for the same ring step is still pending.
    #[test]
    fn transport_order_ag_posts_before_gemm() {
        let d = 4;
        let journal = Arc::new(Mutex::new(Vec::new()));
        let steps = all_gather_steps(1, d);
        let incoming: Vec<Tensor2> = (0..d - 1).map(|i| tile(i as f32)).collect();
        let mut io = RingIo::new(
            Box::new(RecordingLink::new(journal.clone(), Vec::new())),
            Box::new(RecordingLink::new(journal.clone(), incoming)),
        );
        let mut tiles: Vec<Option<Arc<Tensor2>>> = vec![None; d];
        tiles[1] = Some(Arc::new(tile(9.0)));
        let gj = journal.clone();
        io.ag_walk(&steps, &mut tiles, |slot, _xt| {
            gj.lock().unwrap().push(format!("gemm-slot{slot}"));
            Ok(Some(()))
        })
        .unwrap();
        let log = journal.lock().unwrap().clone();
        // d steps: steps 0..d-2 are post,gemm,recv; the last is gemm only.
        let mut want = Vec::new();
        for (s, step) in steps.iter().enumerate() {
            want.push(format!("post{s}"));
            want.push(format!("gemm-slot{}", step.compute_tile));
            if s < d - 1 {
                want.push(format!("recv{s}"));
            } else {
                want.pop(); // last step: no post happened
                want.pop();
                want.push(format!("gemm-slot{}", step.compute_tile));
            }
        }
        assert_eq!(log, want, "AG transport order broken");
    }

    #[test]
    fn transport_order_rs_posts_before_gemm() {
        let d = 3;
        let journal = Arc::new(Mutex::new(Vec::new()));
        let steps = reduce_scatter_steps(0, d);
        let incoming: Vec<Tensor2> = (0..d - 1).map(|_| tile(1.0)).collect();
        let mut io = RingIo::new(
            Box::new(RecordingLink::new(journal.clone(), Vec::new())),
            Box::new(RecordingLink::new(journal.clone(), incoming)),
        );
        let gj = journal.clone();
        io.rs_walk(&steps, |slot| {
            gj.lock().unwrap().push(format!("gemm-slot{slot}"));
            Ok(tile(0.5))
        })
        .unwrap();
        let log = journal.lock().unwrap().clone();
        // Step 0: gemm only (nothing accumulated yet). Steps 1..d: the
        // accumulated partial is posted before the step's exit GEMM, and
        // the predecessor's partial reduce-added after.
        assert_eq!(log[0], format!("gemm-slot{}", steps[0].compute_tile));
        let mut k = 1;
        for (s, step) in steps.iter().enumerate().skip(1) {
            assert_eq!(log[k], format!("post{}", s - 1), "RS step {s} must post first");
            assert_eq!(log[k + 1], format!("gemm-slot{}", step.compute_tile));
            assert_eq!(log[k + 2], format!("recv{}", s - 1));
            k += 3;
        }
        assert_eq!(k, log.len());
    }

    #[test]
    fn transport_bytes_counted_only_after_successful_post() {
        // Regression (satellite bugfix): a failing send must not bump the
        // byte counter.
        struct FailingTx;
        impl RingLink for FailingTx {
            fn post_send(&mut self, _t: WireTile) -> Result<()> {
                Err(GalaxyError::Fabric("down".into()))
            }
            fn try_recv(&mut self) -> Result<bool> {
                Ok(false)
            }
            fn complete_recv(&mut self) -> Result<WireTile> {
                Err(GalaxyError::Fabric("down".into()))
            }
            fn stats(&self) -> LinkStats {
                LinkStats::default()
            }
        }
        let (_keep_alive, rx) = threaded_pair().unwrap();
        let mut io = RingIo::new(Box::new(FailingTx), Box::new(rx));
        let steps = all_gather_steps(0, 2);
        let mut tiles = vec![Some(Arc::new(tile(1.0))), None];
        let err = io.ag_walk(&steps, &mut tiles, |_, _| Ok(Some(()))).unwrap_err();
        assert!(matches!(err, GalaxyError::Fabric(_)));
        assert_eq!(io.bytes, 0, "failed send must not count ring bytes");
    }

    #[test]
    fn transport_mem_link_backpressures_on_third_tile() {
        let (mut tx, mut rx) = mem_link_pair(LINK_SLOTS);
        tx.post_send(WireTile::plain(tile(1.0))).unwrap();
        tx.post_send(WireTile::plain(tile(2.0))).unwrap();
        let err = tx.post_send(WireTile::plain(tile(3.0))).unwrap_err();
        assert!(err.to_string().contains("backpressure"), "{err}");
        // Consuming one frees a slot.
        assert!(rx.try_recv().unwrap());
        let got = rx.complete_recv().unwrap().decode().unwrap();
        assert_eq!(*got, tile(1.0));
        tx.post_send(WireTile::plain(tile(3.0))).unwrap();
        assert_eq!(*rx.complete_recv().unwrap().decode().unwrap(), tile(2.0));
        assert_eq!(*rx.complete_recv().unwrap().decode().unwrap(), tile(3.0));
        let err = rx.complete_recv().unwrap_err();
        assert!(err.to_string().contains("deadlock"), "{err}");
    }

    #[test]
    fn transport_mem_link_forwards_by_refcount_not_copy() {
        // Satellite fix: a transited F32 tile is shared, never cloned —
        // the payload a receiver decodes is the very allocation the
        // sender posted.
        let (mut tx, mut rx) = mem_link_pair(LINK_SLOTS);
        let payload = Arc::new(tile(7.0));
        let codec = TileCodec::new(WireFormat::F32);
        tx.post_send(codec.encode(&payload).unwrap()).unwrap();
        assert_eq!(Arc::strong_count(&payload), 2, "the queue holds a ref, not a copy");
        let got = rx.complete_recv().unwrap().decode().unwrap();
        assert!(Arc::ptr_eq(&payload, &got), "forward path must be zero-copy");
        assert_eq!(codec.pool_stats().unwrap(), PoolStats::default());
    }

    #[test]
    fn transport_wrong_direction_is_an_error() {
        let (mut tx, mut rx) = mem_link_pair(LINK_SLOTS);
        assert!(tx.try_recv().is_err());
        assert!(tx.complete_recv().is_err());
        assert!(rx.post_send(WireTile::plain(tile(0.0))).is_err());
        let (mut ttx, mut trx) = threaded_pair().unwrap();
        assert!(ttx.try_recv().is_err());
        assert!(trx.post_send(WireTile::plain(tile(0.0))).is_err());
    }

    #[test]
    fn transport_threaded_backpressure_on_third_tile() {
        let (mut tx, mut rx) = threaded_pair().unwrap();
        // Two posts return without a consumer; the third blocks until a
        // slot frees (asserted via a flag the posting thread sets).
        tx.post_send(WireTile::plain(tile(1.0))).unwrap();
        tx.post_send(WireTile::plain(tile(2.0))).unwrap();
        let done = Arc::new(AtomicBool::new(false));
        let done2 = done.clone();
        let h = std::thread::spawn(move || {
            tx.post_send(WireTile::plain(tile(3.0))).unwrap();
            done2.store(true, Ordering::SeqCst);
            tx // keep the endpoint alive until joined
        });
        std::thread::sleep(Duration::from_millis(50));
        assert!(!done.load(Ordering::SeqCst), "third post must backpressure");
        assert_eq!(*rx.complete_recv().unwrap().decode().unwrap(), tile(1.0));
        let tx = h.join().unwrap();
        assert!(done.load(Ordering::SeqCst));
        assert_eq!(*rx.complete_recv().unwrap().decode().unwrap(), tile(2.0));
        assert_eq!(*rx.complete_recv().unwrap().decode().unwrap(), tile(3.0));
        assert_eq!(tx.stats().tiles, 3);
        assert_eq!(rx.stats().tiles, 3);
        assert!(rx.stats().exposed_s >= 0.0 && rx.stats().hidden_s >= 0.0);
    }

    #[test]
    fn transport_dropped_sender_unblocks_receiver() {
        // A dead neighbor must surface as a Fabric error, not a hang.
        let (tx, mut rx) = threaded_pair().unwrap();
        drop(tx);
        let err = rx.complete_recv().unwrap_err();
        assert!(matches!(err, GalaxyError::Fabric(_)), "{err}");
        assert!(rx.try_recv().is_err());
    }

    #[test]
    fn transport_dropped_receiver_unblocks_sender() {
        let (mut tx, rx) = threaded_pair().unwrap();
        tx.post_send(WireTile::plain(tile(1.0))).unwrap();
        drop(rx);
        // The in-flight tile is lost with the receiver; subsequent posts
        // must error out once the io-thread has noticed (bounded retries
        // absorb the shutdown race).
        let mut failed = false;
        for _ in 0..50 {
            if tx.post_send(WireTile::plain(tile(2.0))).is_err() {
                failed = true;
                break;
            }
            std::thread::sleep(Duration::from_millis(2));
        }
        assert!(failed, "posts to a dropped receiver must eventually fail");
    }

    #[test]
    fn transport_threaded_ring_runs_a_real_all_gather() {
        // d workers on threads, each walking the same AG schedule the
        // cluster workers use; every device must end with the reference
        // concat, and hidden+exposed accounting must cover every tile.
        let d = 3;
        let shards: Vec<Tensor2> = (0..d).map(|i| tile(i as f32)).collect();
        let want = reference::all_gather(&shards).unwrap();
        let ios = threaded_ring(d).unwrap();
        let mut handles = Vec::new();
        for (i, mut io) in ios.into_iter().enumerate() {
            let my = shards[i].clone();
            handles.push(std::thread::spawn(move || {
                let steps = all_gather_steps(i, d);
                let mut tiles: Vec<Option<Arc<Tensor2>>> = vec![None; d];
                tiles[i] = Some(Arc::new(my));
                io.ag_walk(&steps, &mut tiles, |_, _| {
                    // Stand-in for the entry GEMM the transfer overlaps.
                    std::thread::sleep(Duration::from_millis(1));
                    Ok(Some(()))
                })
                .unwrap();
                let parts: Vec<Tensor2> =
                    tiles.into_iter().map(|t| take_tile(t.expect("gathered"))).collect();
                (Tensor2::concat_rows(&parts).unwrap(), io.bytes, io.link_stats())
            }));
        }
        for h in handles {
            let (got, bytes, stats) = h.join().unwrap();
            assert_eq!(got, want);
            assert_eq!(bytes, (d as u64 - 1) * shards[0].size_bytes() as u64);
            assert_eq!(stats.tiles, 2 * (d as u64 - 1)); // sent + received
            assert!(stats.exposed_s >= 0.0 && stats.hidden_s >= 0.0);
        }
    }

    #[test]
    fn transport_micro_ag_matches_coarse_bit_exact() {
        // Grain 2d over an uneven SP partition: the gathered slots must
        // be bit-identical to the reference concat (pure row slicing and
        // reassembly at f32), the GEMM must fire once per tile — not per
        // micro — and the encoded ring volume must equal the coarse
        // walk's (same tiles transit, just sliced).
        let d = 3;
        let grain = 2 * d;
        let rows = [4usize, 3, 5];
        let shards: Vec<Tensor2> = (0..d)
            .map(|t| {
                Tensor2::from_vec(
                    rows[t],
                    3,
                    (0..rows[t] * 3).map(|k| (t * 100 + k) as f32 * 0.5 - 7.0).collect(),
                )
                .unwrap()
            })
            .collect();
        let want = reference::all_gather(&shards).unwrap();
        let ios = threaded_ring(d).unwrap();
        let mut handles = Vec::new();
        for (i, mut io) in ios.into_iter().enumerate() {
            let my = shards[i].clone();
            handles.push(std::thread::spawn(move || {
                let steps = all_gather_micro_steps(i, d, grain);
                let mut tiles: Vec<Option<Arc<Tensor2>>> = vec![None; d];
                tiles[i] = Some(Arc::new(my));
                let outs = io
                    .ag_walk_micro(&steps, grain, &mut tiles, |_, _| Ok(Some(())))
                    .unwrap();
                assert_eq!(outs.iter().flatten().count(), d, "one GEMM per tile");
                let parts: Vec<Tensor2> =
                    tiles.into_iter().map(|t| take_tile(t.expect("gathered"))).collect();
                (Tensor2::concat_rows(&parts).unwrap(), io.bytes)
            }));
        }
        for (i, h) in handles.into_iter().enumerate() {
            let (got, bytes) = h.join().unwrap();
            assert_eq!(got, want, "device {i}: micro AG must be bit-exact at f32");
            let coarse: u64 =
                (0..d - 1).map(|s| (rows[(i + d - s) % d] * 3 * 4) as u64).sum();
            assert_eq!(bytes, coarse, "device {i}: grain must not change ring bytes");
        }
    }

    #[test]
    fn transport_micro_rs_matches_coarse_bit_exact() {
        // Per element the micro RS applies the same f32 additions in the
        // same hop order as the coarse walk, so the reduced tiles must
        // agree to the bit, not within a tolerance.
        const D: usize = 4;
        const ROWS: [usize; D] = [3, 5, 4, 3];
        fn partial(i: usize, t: usize) -> Tensor2 {
            Tensor2::from_vec(
                ROWS[t],
                2,
                (0..ROWS[t] * 2).map(|k| ((i * 31 + t * 7 + k) as f32).sin()).collect(),
            )
            .unwrap()
        }
        let run = |micro: bool| -> Vec<Tensor2> {
            let ios = threaded_ring(D).unwrap();
            let mut handles = Vec::new();
            for (i, mut io) in ios.into_iter().enumerate() {
                handles.push(std::thread::spawn(move || {
                    if micro {
                        let grain = 2 * D;
                        let steps = reduce_scatter_micro_steps(i, D, grain);
                        io.rs_walk_micro(&steps, grain, |t| Ok(partial(i, t))).unwrap()
                    } else {
                        let steps = reduce_scatter_steps(i, D);
                        io.rs_walk(&steps, |t| Ok(partial(i, t))).unwrap()
                    }
                }));
            }
            handles.into_iter().map(|h| h.join().unwrap()).collect()
        };
        let coarse = run(false);
        let micro = run(true);
        assert_eq!(micro, coarse, "micro RS must reproduce the coarse reduction bit-exactly");
    }

    #[test]
    fn transport_micro_order_one_post_per_substep() {
        // The slot-safety core of the grain contract: every sub-step
        // posts exactly one micro-tile and consumes exactly one, the
        // GEMM fires only at a tile's first sub-step — so lockstep skew
        // stays at one sub-step and backpressure still triggers at
        // LINK_SLOTS regardless of the grain.
        let d = 3;
        let grain = 2 * d; // per = 2
        let journal = Arc::new(Mutex::new(Vec::new()));
        let steps = all_gather_micro_steps(1, d, grain);
        let incoming: Vec<Tensor2> = (0..(d - 1) * 2).map(|i| tile(i as f32)).collect();
        let mut io = RingIo::new(
            Box::new(RecordingLink::new(journal.clone(), Vec::new())),
            Box::new(RecordingLink::new(journal.clone(), incoming)),
        );
        let mut tiles: Vec<Option<Arc<Tensor2>>> = vec![None; d];
        tiles[1] = Some(Arc::new(tile(9.0)));
        let gj = journal.clone();
        io.ag_walk_micro(&steps, grain, &mut tiles, |slot, _xt| {
            gj.lock().unwrap().push(format!("gemm-slot{slot}"));
            Ok(Some(()))
        })
        .unwrap();
        let log = journal.lock().unwrap().clone();
        let want: Vec<String> = [
            // step 0 (own tile 1): micro 0 posts, GEMM, reap; micro 1
            // posts and reaps with no second GEMM.
            "post0", "gemm-slot1", "recv0", "post1", "recv1",
            // step 1 (transited tile 0, reassembled from two micros).
            "post2", "gemm-slot0", "recv2", "post3", "recv3",
            // final step: silent, GEMM only.
            "gemm-slot2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        assert_eq!(log, want, "micro AG transport order broken");
    }

    #[test]
    fn transport_micro_walk_rejects_bad_grain() {
        let (tx, rx) = mem_link_pair(LINK_SLOTS);
        let mut io = RingIo::new(Box::new(tx), Box::new(rx));
        let steps = all_gather_micro_steps(0, 2, 4);
        let mut tiles = vec![Some(Arc::new(tile(1.0))), None];
        // Grain not a multiple of the ring size.
        let err = io.ag_walk_micro(&steps, 3, &mut tiles, |_, _| Ok(Some(()))).unwrap_err();
        assert!(err.to_string().contains("multiple of the ring size"), "{err}");
        // More micro-tiles than rows: the 2-row tile cannot split 4 ways.
        let err = io.ag_walk_micro(&steps, 8, &mut tiles, |_, _| Ok(Some(()))).unwrap_err();
        assert!(err.to_string().contains("micro-tiles"), "{err}");
    }

    #[test]
    fn transport_quantized_walk_counts_encoded_bytes() {
        // The byte counter reports the wire truth: an I8 walk moves a
        // quarter of the F32 volume for the same schedule.
        let d = 4;
        let journal = Arc::new(Mutex::new(Vec::new()));
        let steps = all_gather_steps(1, d);
        let incoming: Vec<Tensor2> = (0..d - 1).map(|i| tile(i as f32)).collect();
        let mut io = RingIo::with_format(
            Box::new(RecordingLink::new(journal.clone(), Vec::new())),
            Box::new(RecordingLink::new(journal, incoming)),
            WireFormat::I8,
        );
        assert_eq!(io.wire_format(), WireFormat::I8);
        let mut tiles: Vec<Option<Arc<Tensor2>>> = vec![None; d];
        tiles[1] = Some(Arc::new(tile(9.0)));
        io.ag_walk(&steps, &mut tiles, |_, _| Ok(Some(()))).unwrap();
        let elems = tile(0.0).len() as u64;
        assert_eq!(io.bytes, (d as u64 - 1) * elems, "i8 moves 1 B/elem");
        let pool = io.pool_stats().unwrap();
        assert_eq!(pool.hits + pool.allocs, d as u64 - 1);
    }

    #[test]
    fn transport_steady_state_posting_never_allocates() {
        // The no-alloc-per-post contract: after the first round leases
        // its buffers, every further quantized post is a pool hit.
        let d = 2;
        let rounds = 30;
        let ios = threaded_ring_with(d, WireFormat::I8).unwrap();
        let mut handles = Vec::new();
        for (i, mut io) in ios.into_iter().enumerate() {
            handles.push(std::thread::spawn(move || {
                let steps = all_gather_steps(i, d);
                for r in 0..rounds {
                    let mut tiles: Vec<Option<Arc<Tensor2>>> = vec![None; d];
                    tiles[i] = Some(Arc::new(tile(r as f32 + 1.0)));
                    io.ag_walk(&steps, &mut tiles, |_, _| Ok(Some(()))).unwrap();
                }
                io.pool_stats().unwrap()
            }));
        }
        for h in handles {
            let stats = h.join().unwrap();
            assert_eq!(stats.hits + stats.allocs, rounds as u64, "one lease per post");
            assert!(
                stats.allocs <= LINK_SLOTS as u64 + 1,
                "steady-state posts must reuse pooled buffers, allocated {}",
                stats.allocs
            );
            assert!(stats.hit_rate() > 0.8, "hit rate {}", stats.hit_rate());
        }
    }

    #[test]
    fn transport_quantized_ring_all_gather_stays_within_bounds() {
        // A real threaded AG under each lossy format lands within the
        // format's stated error bound of the exact gather.
        let d = 3;
        let mut vals = Vec::new();
        let mut seed = 0.05f32;
        for _ in 0..d {
            let t = Tensor2::from_vec(2, 3, (0..6).map(|k| {
                seed = (seed * 1.7 + 0.3) % 2.0 - 1.0;
                seed * (k as f32 + 1.0)
            }).collect())
            .unwrap();
            vals.push(t);
        }
        let want = reference::all_gather(&vals).unwrap();
        for format in [WireFormat::F16, WireFormat::I8] {
            let ios = threaded_ring_with(d, format).unwrap();
            let mut handles = Vec::new();
            for (i, mut io) in ios.into_iter().enumerate() {
                let my = vals[i].clone();
                handles.push(std::thread::spawn(move || {
                    let steps = all_gather_steps(i, d);
                    let mut tiles: Vec<Option<Arc<Tensor2>>> = vec![None; d];
                    tiles[i] = Some(Arc::new(my));
                    io.ag_walk(&steps, &mut tiles, |_, _| Ok(Some(()))).unwrap();
                    let parts: Vec<Tensor2> =
                        tiles.into_iter().map(|t| take_tile(t.expect("gathered"))).collect();
                    Tensor2::concat_rows(&parts).unwrap()
                }));
            }
            // AG re-encoding is idempotent, so even the farthest-traveled
            // tile carries one encode's error (plus ulp-level scale drift).
            let (rtol, atol) = match format {
                WireFormat::F16 => (1e-3, 1e-4),
                _ => (1e-2, 6e-2),
            };
            for h in handles {
                let got = h.join().unwrap();
                assert!(
                    got.allclose(&want, rtol, atol),
                    "{format}: diff {}",
                    got.max_abs_diff(&want).unwrap()
                );
            }
        }
    }
}
