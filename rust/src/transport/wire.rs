//! Quantized wire formats + pooled encode buffers for the ring transport.
//!
//! The ring moves activation tiles, and bytes are the cost the paper's
//! bandwidth sweep (§V) punishes hardest — so the transport can encode
//! tiles before they hit the wire. Three formats:
//!
//! * [`WireFormat::F32`] — the framework default (4 B/elem). Encoding is
//!   a refcount bump: the payload is the `Arc<Tensor2>` itself, so an
//!   F32 post copies **nothing** and an in-process forward is pointer-
//!   sized.
//! * [`WireFormat::F16`] — IEEE 754 binary16, 2 B/elem, hand-rolled bit
//!   conversion (the offline registry has no `half` crate). Round-off is
//!   ≤ 2⁻¹¹ relative in the normal range.
//! * [`WireFormat::I8`] — symmetric **per-channel** int8: every row
//!   (sequence position) gets its own `scale = max|row|/127`,
//!   `q = round(x/scale)`, 1 B/elem. A per-tile scale let one outlier
//!   row blow up the quantization error of every other row; row-wise
//!   max-abs bounds each row's error by its *own* magnitude. The scale
//!   vector rides in the tile header (out of band, excluded from byte
//!   accounting — 4 B per row against KBs of payload, and excluding it
//!   keeps the modeled and measured `ring_bytes` exactly
//!   `elems × elem_bytes` on both engines).
//!
//! Re-encoding a decoded tile is **idempotent** for both lossy formats
//! (each row's max element quantizes to exactly ±127, so the row's scale
//! is a fixed point): an AllGather hop chain adds no error beyond the
//! first encode. A ReduceScatter *does* compound — each hop re-quantizes the
//! running partial sum — so its error bound grows with the ring size
//! (the collective parity tests pin both bounds).
//!
//! # Pool lease contract
//!
//! Lossy encodes write into a [`TileBuf`] leased from a [`TileBufPool`]
//! instead of allocating per post. The lease follows the tile: it
//! travels to the receiving endpoint inside the [`WireTile`], and when
//! the decoded tile drops the buffer returns to its **origin** pool
//! (cross-thread safe — the pool is `Arc<Mutex<…>>` and the lease holds
//! a weak handle, so an outliving buffer never keeps a dead pool
//! alive). With `LINK_SLOTS` tiles in flight a ring steady-states on a
//! handful of buffers; [`PoolStats`] counts leases served from the free
//! list (`hits`) vs fresh allocations (`allocs`), which is what the
//! no-alloc-per-post property test and the transport bench's pool hit
//! rate read.

use std::fmt;

use super::sync::{fabric_lock, Arc, Mutex, Weak};
use crate::error::{GalaxyError, Result};
use crate::tensor::Tensor2;

/// Encoding of activation tiles on the ring wire.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum WireFormat {
    /// 4 B/elem, exact; payload is a refcounted `Tensor2` (zero-copy).
    #[default]
    F32,
    /// 2 B/elem IEEE binary16; ≤ 2⁻¹¹ relative round-off per encode.
    F16,
    /// 1 B/elem symmetric int8 with a per-channel (row-wise max-abs)
    /// scale; ≤ `max|row|/254` absolute error per encode, per row.
    I8,
}

impl WireFormat {
    /// Bytes per activation element on the wire.
    pub fn elem_bytes(self) -> usize {
        match self {
            WireFormat::F32 => 4,
            WireFormat::F16 => 2,
            WireFormat::I8 => 1,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            WireFormat::F32 => "f32",
            WireFormat::F16 => "f16",
            WireFormat::I8 => "i8",
        }
    }

    /// Parse a CLI/config spelling (`f32`, `f16`, `i8`).
    pub fn parse(s: &str) -> Result<Self> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" => Ok(WireFormat::F32),
            "f16" | "fp16" => Ok(WireFormat::F16),
            "i8" | "int8" => Ok(WireFormat::I8),
            other => Err(GalaxyError::Config(format!(
                "unknown wire format `{other}` (expected f32, f16 or i8)"
            ))),
        }
    }

    /// All formats, for sweeps and parity tests.
    pub fn all() -> [WireFormat; 3] {
        [WireFormat::F32, WireFormat::F16, WireFormat::I8]
    }
}

impl fmt::Display for WireFormat {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

// ---------------------------------------------------------------------
// f32 <-> f16 bit conversion (no `half` crate in the offline registry)
// ---------------------------------------------------------------------

/// Convert an `f32` to IEEE binary16 bits, round-to-nearest-even.
pub fn f32_to_f16_bits(value: f32) -> u16 {
    let x = value.to_bits();
    let sign = (x >> 16) & 0x8000;
    let mut mantissa = x & 0x007f_ffff;
    let exp = (x >> 23) & 0xff;
    if exp == 255 {
        // Inf / NaN (keep a payload bit so NaN stays NaN).
        let m = if mantissa != 0 { 0x0200 } else { 0 };
        return (sign | 0x7c00 | m) as u16;
    }
    let e = exp as i32 - 127 + 15;
    if e >= 31 {
        return (sign | 0x7c00) as u16; // overflow → ±inf
    }
    if e <= 0 {
        if e < -10 {
            return sign as u16; // underflow → ±0
        }
        // Subnormal half: shift the 24-bit significand into the 10-bit
        // field, round to nearest even.
        mantissa |= 0x0080_0000;
        let shift = (14 - e) as u32;
        let half = mantissa >> shift;
        let rem = mantissa & ((1u32 << shift) - 1);
        let halfway = 1u32 << (shift - 1);
        let rounded = half + u32::from(rem > halfway || (rem == halfway && (half & 1) == 1));
        return (sign | rounded) as u16;
    }
    let half = ((e as u32) << 10) | (mantissa >> 13);
    let rem = mantissa & 0x1fff;
    // Round to nearest even; a carry propagates correctly into the
    // exponent (1.11…1 rounds up to the next power of two / to inf).
    let rounded = half + u32::from(rem > 0x1000 || (rem == 0x1000 && (half & 1) == 1));
    (sign | rounded) as u16
}

/// Convert IEEE binary16 bits back to `f32` (exact — every half value is
/// representable in single precision).
pub fn f16_bits_to_f32(h: u16) -> f32 {
    let sign = ((h & 0x8000) as u32) << 16;
    let exp = ((h >> 10) & 0x1f) as u32;
    let man = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if man == 0 {
            sign // ±0
        } else {
            // Subnormal half: normalize into an f32 exponent.
            let mut e: i32 = 113;
            let mut m = man;
            while m & 0x400 == 0 {
                m <<= 1;
                e -= 1;
            }
            sign | ((e as u32) << 23) | ((m & 0x3ff) << 13)
        }
    } else if exp == 31 {
        sign | 0x7f80_0000 | (man << 13) // Inf / NaN
    } else {
        sign | ((exp + 112) << 23) | (man << 13)
    };
    f32::from_bits(bits)
}

// ---------------------------------------------------------------------
// Buffer pool
// ---------------------------------------------------------------------

/// Pool accounting: every lease is either a `hit` (served from the free
/// list) or an `alloc` (fresh allocation).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolStats {
    pub hits: u64,
    pub allocs: u64,
}

impl PoolStats {
    /// Fraction of leases served without allocating (0 when unused).
    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.allocs;
        if total == 0 {
            0.0
        } else {
            self.hits as f64 / total as f64
        }
    }
}

#[derive(Default)]
struct PoolInner {
    free: Vec<Vec<u8>>,
    stats: PoolStats,
}

/// Shared free-list of encode buffers (see module docs for the lease
/// contract). Cloning shares the pool.
#[derive(Clone, Default)]
pub struct TileBufPool {
    inner: Arc<Mutex<PoolInner>>,
}

impl TileBufPool {
    pub fn new() -> Self {
        Self::default()
    }

    /// Lease a buffer with capacity for at least `len` bytes. The buffer
    /// comes back empty; it returns to this pool when the lease drops.
    /// A poisoned pool (a peer thread died mid-lease) degrades to a
    /// [`GalaxyError::Fabric`] error, like a dead neighbor — it never
    /// aborts the process.
    pub fn lease(&self, len: usize) -> Result<TileBuf> {
        let mut g = fabric_lock(&self.inner, "tile pool lease")?;
        let mut data = match g.free.iter().position(|b| b.capacity() >= len) {
            Some(i) => {
                g.stats.hits += 1;
                g.free.swap_remove(i)
            }
            None => {
                g.stats.allocs += 1;
                Vec::with_capacity(len)
            }
        };
        data.clear();
        Ok(TileBuf { data, pool: Arc::downgrade(&self.inner) })
    }

    pub fn stats(&self) -> Result<PoolStats> {
        Ok(fabric_lock(&self.inner, "tile pool stats")?.stats)
    }
}

/// A pooled byte buffer: dereferences to its bytes, returns to its
/// origin pool on drop (no-op if the pool is gone).
pub struct TileBuf {
    data: Vec<u8>,
    pool: Weak<Mutex<PoolInner>>,
}

impl TileBuf {
    /// A free-standing buffer not backed by any pool (tests, one-shots).
    pub fn unpooled(data: Vec<u8>) -> Self {
        Self { data, pool: Weak::new() }
    }

    pub fn as_slice(&self) -> &[u8] {
        &self.data
    }

    fn push_u16(&mut self, v: u16) {
        self.data.extend_from_slice(&v.to_le_bytes());
    }
}

impl Drop for TileBuf {
    fn drop(&mut self) {
        if let Some(pool) = self.pool.upgrade() {
            if let Ok(mut g) = pool.lock() {
                g.free.push(std::mem::take(&mut self.data));
            }
        }
    }
}

// ---------------------------------------------------------------------
// Wire tiles + codec
// ---------------------------------------------------------------------

enum Payload {
    F32(Arc<Tensor2>),
    F16(TileBuf),
    /// Row-major int8 codes plus one scale per row (per-channel
    /// quantization: row `r` decodes as `code × scales[r]`).
    I8 { buf: TileBuf, scales: Vec<f32> },
}

/// One encoded tile as it travels a ring link: shape header + payload.
/// Produced by [`TileCodec::encode`] (or [`WireTile::plain`] for raw
/// F32), consumed by [`WireTile::decode`].
pub struct WireTile {
    rows: usize,
    cols: usize,
    payload: Payload,
}

impl WireTile {
    /// Wrap an owned tensor as an exact F32 tile (no codec needed).
    pub fn plain(t: Tensor2) -> Self {
        Self { rows: t.rows(), cols: t.cols(), payload: Payload::F32(Arc::new(t)) }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn format(&self) -> WireFormat {
        match self.payload {
            Payload::F32(_) => WireFormat::F32,
            Payload::F16(_) => WireFormat::F16,
            Payload::I8 { .. } => WireFormat::I8,
        }
    }

    /// Payload bytes this tile occupies on the wire: `elems × elem_bytes`
    /// (the I8 scale is header, not payload — see module docs).
    pub fn wire_bytes(&self) -> u64 {
        (self.rows * self.cols * self.format().elem_bytes()) as u64
    }

    /// Decode back to a tensor. F32 is a refcount move (zero-copy);
    /// lossy formats reconstruct and release their pooled buffer. Errors
    /// only on a corrupt header (payload length disagreeing with the
    /// tile's stated shape) — a `Fabric` fault, never a panic.
    pub fn decode(self) -> Result<Arc<Tensor2>> {
        let (rows, cols) = (self.rows, self.cols);
        match self.payload {
            Payload::F32(t) => Ok(t),
            Payload::F16(buf) => {
                let data: Vec<f32> = buf
                    .as_slice()
                    .chunks_exact(2)
                    .map(|c| f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
                    .collect();
                Ok(Arc::new(Tensor2::from_vec(rows, cols, data)?))
            }
            Payload::I8 { buf, scales } => {
                if scales.len() != rows {
                    return Err(GalaxyError::Fabric(format!(
                        "i8 tile header corrupt: {} scales for {rows} rows",
                        scales.len()
                    )));
                }
                let mut data = Vec::with_capacity(rows * cols);
                for (r, row) in buf.as_slice().chunks_exact(cols.max(1)).enumerate() {
                    let scale = scales[r];
                    data.extend(row.iter().map(|&b| (b as i8) as f32 * scale));
                }
                Ok(Arc::new(Tensor2::from_vec(rows, cols, data)?))
            }
        }
    }
}

/// Encoder for one ring endpoint: a wire format plus the buffer pool its
/// lossy encodes lease from.
pub struct TileCodec {
    format: WireFormat,
    pool: TileBufPool,
}

impl TileCodec {
    pub fn new(format: WireFormat) -> Self {
        Self { format, pool: TileBufPool::new() }
    }

    /// Share an existing pool (e.g. one pool across a lockstep ring).
    pub fn with_pool(format: WireFormat, pool: TileBufPool) -> Self {
        Self { format, pool }
    }

    pub fn format(&self) -> WireFormat {
        self.format
    }

    pub fn pool_stats(&self) -> Result<PoolStats> {
        self.pool.stats()
    }

    /// Encode a tile for the wire. F32 bumps the refcount; F16/I8 write
    /// into a pooled buffer (errors if the pool lock was poisoned by a
    /// failed peer thread).
    pub fn encode(&self, t: &Arc<Tensor2>) -> Result<WireTile> {
        let (rows, cols) = (t.rows(), t.cols());
        let payload = match self.format {
            WireFormat::F32 => Payload::F32(t.clone()),
            WireFormat::F16 => {
                let mut buf = self.pool.lease(t.len() * 2)?;
                for &x in t.data() {
                    buf.push_u16(f32_to_f16_bits(x));
                }
                Payload::F16(buf)
            }
            WireFormat::I8 => {
                let mut buf = self.pool.lease(t.len())?;
                let mut scales = Vec::with_capacity(rows);
                for r in 0..rows {
                    let row = t.row(r);
                    let max_abs = row.iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                    let scale = max_abs / 127.0;
                    scales.push(scale);
                    if scale == 0.0 {
                        buf.data.resize(buf.data.len() + cols, 0);
                    } else {
                        for &x in row {
                            let q = (x / scale).round().clamp(-127.0, 127.0) as i8;
                            buf.data.push(q as u8);
                        }
                    }
                }
                Payload::I8 { buf, scales }
            }
        };
        Ok(WireTile { rows, cols, payload })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Pcg64};

    fn rand_tensor(rng: &mut Pcg64, rows: usize, cols: usize) -> Tensor2 {
        Tensor2::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal()).collect()).unwrap()
    }

    #[test]
    fn wire_format_parse_and_names() {
        assert_eq!(WireFormat::parse("f32").unwrap(), WireFormat::F32);
        assert_eq!(WireFormat::parse("FP16").unwrap(), WireFormat::F16);
        assert_eq!(WireFormat::parse("int8").unwrap(), WireFormat::I8);
        assert!(WireFormat::parse("q4").is_err());
        assert_eq!(WireFormat::I8.to_string(), "i8");
        assert_eq!(
            WireFormat::all().map(|f| f.elem_bytes()),
            [4, 2, 1],
            "elem widths are the whole point"
        );
        assert_eq!(WireFormat::default(), WireFormat::F32);
    }

    #[test]
    fn f16_known_values_round_trip_exactly() {
        // Values exactly representable in binary16 must survive unchanged.
        for &x in &[0.0f32, -0.0, 1.0, -1.0, 0.5, 2.0, 65504.0, -65504.0, 6.103515625e-5] {
            let back = f16_bits_to_f32(f32_to_f16_bits(x));
            assert_eq!(back.to_bits(), x.to_bits(), "{x}");
        }
        assert!(f16_bits_to_f32(f32_to_f16_bits(f32::NAN)).is_nan());
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(f32::INFINITY)), f32::INFINITY);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e9)), f32::INFINITY, "overflow → inf");
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(1e-9)), 0.0, "underflow → 0");
        // Subnormal half: 2^-24 is the smallest positive binary16 value.
        let tiny = 2.0f32.powi(-24);
        assert_eq!(f16_bits_to_f32(f32_to_f16_bits(tiny)), tiny);
    }

    #[test]
    fn prop_f16_round_trip_error_bound() {
        // Normal range: relative error ≤ 2^-11 (half ulp of a 10-bit
        // significand); below 2^-14 the error is bounded by the
        // subnormal quantum 2^-25.
        forall(
            "f16 round-trip bound",
            31,
            300,
            |rng| rng.normal() * 10f32.powi(rng.range(0, 6) as i32 - 3),
            |&x| {
                let back = f16_bits_to_f32(f32_to_f16_bits(x));
                let bound = (x.abs() * 2f32.powi(-11)).max(2f32.powi(-25));
                if (back - x).abs() <= bound {
                    Ok(())
                } else {
                    Err(format!("|{back} - {x}| > {bound}"))
                }
            },
        );
    }

    #[test]
    fn prop_i8_round_trip_error_bound() {
        // Symmetric per-channel int8: each row's absolute error is
        // bounded by *its own* half-quantum, scale/2 = max|row|/254 —
        // strictly tighter than the old per-tile max|x|/254 bound.
        forall(
            "i8 per-row round-trip bound",
            32,
            100,
            |rng| {
                let rows = rng.range(1, 8) as usize;
                let cols = rng.range(1, 8) as usize;
                rand_tensor(rng, rows, cols)
            },
            |t| {
                let codec = TileCodec::new(WireFormat::I8);
                let arc = Arc::new(t.clone());
                let back = codec.encode(&arc).unwrap().decode().unwrap();
                for r in 0..t.rows() {
                    let row_max = t.row(r).iter().fold(0.0f32, |m, &x| m.max(x.abs()));
                    let bound = row_max / 254.0 + 1e-7;
                    for (a, b) in t.row(r).iter().zip(back.row(r)) {
                        if (a - b).abs() > bound {
                            return Err(format!("row {r}: |{a} - {b}| > {bound}"));
                        }
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn i8_per_channel_scales_isolate_outlier_rows() {
        // The point of row-wise scales: a huge row must not degrade a
        // tiny row's precision. Under a per-tile scale the small row
        // would quantize entirely to zero (error ≈ 0.01 ≫ 100/254 is
        // false the other way: quantum 100/127 ≈ 0.79 swallows it);
        // per-channel keeps its error at its own half-quantum.
        let big = vec![100.0f32, -55.0, 73.0, 9.0];
        let small = vec![0.011f32, -0.007, 0.0042, 0.0099];
        let t = Arc::new(
            Tensor2::from_vec(2, 4, big.iter().chain(&small).copied().collect()).unwrap(),
        );
        let codec = TileCodec::new(WireFormat::I8);
        let back = codec.encode(&t).unwrap().decode().unwrap();
        let small_bound = 0.011 / 254.0 + 1e-7;
        for (a, b) in t.row(1).iter().zip(back.row(1)) {
            assert!(
                (a - b).abs() <= small_bound,
                "outlier row degraded a small row: |{a} - {b}| > {small_bound}"
            );
        }
        let big_bound = 100.0 / 254.0 + 1e-6;
        for (a, b) in t.row(0).iter().zip(back.row(0)) {
            assert!((a - b).abs() <= big_bound, "|{a} - {b}| > {big_bound}");
        }
    }

    #[test]
    fn lossy_re_encode_is_idempotent() {
        // The AG-hop invariant: encode∘decode is a projection, so a tile
        // forwarded d-1 hops carries only the first encode's error. The
        // per-hop scale may drift by an ulp, never the quantized codes.
        let mut rng = Pcg64::new(33);
        for format in [WireFormat::F16, WireFormat::I8] {
            let codec = TileCodec::new(format);
            let mut t = Arc::new(rand_tensor(&mut rng, 6, 5));
            let first = codec.encode(&t).unwrap().decode().unwrap();
            t = first.clone();
            for hop in 0..4 {
                t = codec.encode(&t).unwrap().decode().unwrap();
                assert!(
                    t.allclose(&first, 1e-6, 1e-9),
                    "{format}: hop {hop} drifted beyond ulp noise"
                );
            }
        }
    }

    #[test]
    fn i8_all_zero_tile_is_exact() {
        let codec = TileCodec::new(WireFormat::I8);
        let z = Arc::new(Tensor2::zeros(3, 4));
        let back = codec.encode(&z).unwrap().decode().unwrap();
        assert_eq!(*back, *z, "zero tile must not divide by a zero scale");
    }

    #[test]
    fn f32_encode_is_a_refcount_bump() {
        let codec = TileCodec::new(WireFormat::F32);
        let t = Arc::new(Tensor2::full(2, 2, 3.0));
        let wt = codec.encode(&t).unwrap();
        assert_eq!(Arc::strong_count(&t), 2, "encode must share, not copy");
        let back = wt.decode().unwrap();
        assert!(Arc::ptr_eq(&t, &back), "decode must return the same allocation");
        assert_eq!(codec.pool_stats().unwrap(), PoolStats::default(), "F32 never touches the pool");
    }

    #[test]
    fn wire_bytes_scale_with_the_format() {
        let t = Arc::new(Tensor2::full(4, 8, 1.5));
        for format in WireFormat::all() {
            let codec = TileCodec::new(format);
            let wt = codec.encode(&t).unwrap();
            assert_eq!(wt.format(), format);
            assert_eq!(wt.wire_bytes(), (4 * 8 * format.elem_bytes()) as u64);
            assert_eq!((wt.rows(), wt.cols()), (4, 8));
        }
        assert_eq!(WireTile::plain(Tensor2::zeros(2, 3)).wire_bytes(), 24);
    }

    #[test]
    fn pool_reuses_buffers_after_warmup() {
        // The lease contract: once as many buffers exist as are ever
        // simultaneously live, every further lease is a hit.
        let codec = TileCodec::new(WireFormat::I8);
        let t = Arc::new(Tensor2::full(8, 8, 2.0));
        for _ in 0..3 {
            drop(codec.encode(&t).unwrap()); // warm-up leases, returned on drop
        }
        let after_warmup = codec.pool_stats().unwrap().allocs;
        for _ in 0..50 {
            let wt = codec.encode(&t).unwrap();
            drop(wt.decode().unwrap()); // decode consumes the tile, lease returns
        }
        let stats = codec.pool_stats().unwrap();
        assert_eq!(stats.allocs, after_warmup, "steady state must not allocate");
        assert!(stats.hits >= 50);
        assert!(stats.hit_rate() > 0.9, "hit rate {}", stats.hit_rate());
    }

    #[test]
    fn pool_survives_cross_scope_returns() {
        let pool = TileBufPool::new();
        let codec = TileCodec::with_pool(WireFormat::F16, pool.clone());
        let t = Arc::new(Tensor2::full(4, 4, 1.0));
        let wt = codec.encode(&t).unwrap();
        drop(codec); // codec gone; the lease still knows its pool
        drop(wt);
        assert_eq!(pool.stats().unwrap().allocs, 1);
        let _second = pool.lease(32).unwrap();
        assert_eq!(pool.stats().unwrap().hits, 1, "returned buffer must be reused");
    }
}
