//! The transport's one doorway to threads, locks and clocks —
//! cfg-switched between `std` and the `loom` model checker.
//!
//! Everything concurrent in `transport/` (io-threads, the slot channel,
//! the tile-buffer pool's mutex, the wall clock behind the
//! exposed/hidden split) goes through this module and nothing else; the
//! `transport-sync-shim` lint rule forbids raw `std::sync` /
//! `std::thread` / `std::time::Instant` anywhere else under
//! `transport/`. That discipline is what makes the loom suite honest:
//! under `RUSTFLAGS="--cfg loom"` these re-exports swap to the model
//! checker's primitives, so `tests/loom_transport.rs` explores the
//! *production* slot protocol, not a test double.
//!
//! The bounded channel here replaces `std::sync::mpsc::sync_channel` on
//! the transport hot path for the same reason: std's channel is opaque
//! to the model checker, while this one is built on the shim's own
//! `Mutex`/`Condvar` and therefore schedules under loom. Semantics
//! match what the transport relied on: `capacity ≥ 1` buffers that many
//! items and blocks the sender on a full queue; `capacity == 0` is a
//! rendezvous (send returns only once the receiver has taken the item);
//! dropping the receiver fails senders (current and future), dropping
//! the last sender lets the receiver drain the queue and then fail —
//! dead neighbors poison, they never deadlock.

#[cfg(loom)]
pub use loom::sync::{atomic, Arc, Condvar, Mutex, MutexGuard, Weak};
#[cfg(not(loom))]
pub use std::sync::{atomic, Arc, Condvar, Mutex, MutexGuard, Weak};

use std::collections::VecDeque;

use crate::error::{GalaxyError, Result};

/// Lock a shim mutex, mapping a poisoned lock (a peer thread died while
/// holding it) to the same [`GalaxyError::Fabric`] a dead neighbor
/// produces — the caller's link degrades instead of the process
/// aborting.
pub fn fabric_lock<'a, T>(m: &'a Mutex<T>, what: &str) -> Result<MutexGuard<'a, T>> {
    m.lock().map_err(|_| {
        GalaxyError::Fabric(format!("{what}: lock poisoned by a failed peer thread"))
    })
}

pub mod thread {
    //! Thread spawning for the transport's io-threads. The handle is
    //! deliberately not returned: io-threads are detached and exit when
    //! their channels disconnect (loom joins its model threads itself
    //! at the end of every explored schedule).

    use crate::error::Result;

    #[cfg(not(loom))]
    pub fn spawn_named(name: &str, f: impl FnOnce() + Send + 'static) -> Result<()> {
        std::thread::Builder::new()
            .name(name.to_string())
            .spawn(f)
            .map(|_| ())
            .map_err(|e| crate::error::GalaxyError::Fabric(format!("spawn {name}: {e}")))
    }

    #[cfg(loom)]
    pub fn spawn_named(name: &str, f: impl FnOnce() + Send + 'static) -> Result<()> {
        let _ = name; // loom names its model threads itself
        drop(loom::thread::spawn(f));
        Ok(())
    }
}

pub mod time {
    //! The transport's clock. Under loom, model schedules have no
    //! meaningful wall time, so instants are inert and every span is
    //! zero — the accounting code paths still execute, their sums are
    //! just exactly 0.

    #[cfg(not(loom))]
    pub use std::time::Instant;

    #[cfg(not(loom))]
    pub fn now() -> Instant {
        Instant::now()
    }

    #[cfg(loom)]
    #[derive(Clone, Copy, Debug)]
    pub struct Instant;

    #[cfg(loom)]
    impl Instant {
        pub fn elapsed(&self) -> std::time::Duration {
            std::time::Duration::ZERO
        }
    }

    #[cfg(loom)]
    pub fn now() -> Instant {
        Instant
    }
}

// ---------------------------------------------------------------------
// Bounded channel (model-checkable twin of std::sync::mpsc::sync_channel)
// ---------------------------------------------------------------------

/// Send half disconnected: the receiver is gone.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError;

/// Receive half failed: every sender is gone and the queue is drained.
#[derive(Debug, PartialEq, Eq)]
pub struct RecvError;

/// Non-blocking receive outcomes mirroring `std::sync::mpsc`.
#[derive(Debug, PartialEq, Eq)]
pub enum TryRecvError {
    Empty,
    Disconnected,
}

struct Chan<T> {
    q: VecDeque<T>,
    /// Buffered capacity; 0 selects rendezvous handshakes.
    cap: usize,
    senders: usize,
    receiver_alive: bool,
    /// Items consumed so far — a rendezvous sender's receipt: its item
    /// is delivered once `taken` passes the tick recorded at post time.
    taken: u64,
}

struct Shared<T> {
    m: Mutex<Chan<T>>,
    cv: Condvar,
}

/// Sending half of [`sync_channel`].
pub struct Sender<T> {
    shared: Arc<Shared<T>>,
}

/// Receiving half of [`sync_channel`].
pub struct Receiver<T> {
    shared: Arc<Shared<T>>,
}

/// A bounded channel with `std::sync::mpsc::sync_channel` semantics,
/// built on the shim's lock primitives so loom can model it.
pub fn sync_channel<T>(capacity: usize) -> (Sender<T>, Receiver<T>) {
    let shared = Arc::new(Shared {
        m: Mutex::new(Chan {
            q: VecDeque::new(),
            cap: capacity,
            senders: 1,
            receiver_alive: true,
            taken: 0,
        }),
        cv: Condvar::new(),
    });
    (Sender { shared: shared.clone() }, Receiver { shared })
}

impl<T> Sender<T> {
    /// Block until the item occupies a slot (buffered) or has been taken
    /// by the receiver (rendezvous). Errors once the receiver is gone —
    /// including while blocked, which is what unblocks a backpressured
    /// poster when its neighbor dies.
    pub fn send(&self, value: T) -> std::result::Result<(), SendError> {
        let mut g = self.shared.m.lock().map_err(|_| SendError)?;
        if g.cap == 0 {
            // Rendezvous: park the item, then wait for the receipt.
            while !g.q.is_empty() && g.receiver_alive {
                g = self.shared.cv.wait(g).map_err(|_| SendError)?;
            }
            if !g.receiver_alive {
                return Err(SendError);
            }
            g.q.push_back(value);
            let receipt = g.taken + 1;
            self.shared.cv.notify_all();
            while g.taken < receipt && g.receiver_alive {
                g = self.shared.cv.wait(g).map_err(|_| SendError)?;
            }
            if g.taken < receipt {
                return Err(SendError);
            }
            return Ok(());
        }
        while g.q.len() >= g.cap && g.receiver_alive {
            g = self.shared.cv.wait(g).map_err(|_| SendError)?;
        }
        if !g.receiver_alive {
            return Err(SendError);
        }
        g.q.push_back(value);
        self.shared.cv.notify_all();
        Ok(())
    }
}

impl<T> Receiver<T> {
    /// Block until an item arrives. Drains buffered items even after
    /// every sender dropped, then errors.
    pub fn recv(&self) -> std::result::Result<T, RecvError> {
        let mut g = self.shared.m.lock().map_err(|_| RecvError)?;
        loop {
            if let Some(v) = g.q.pop_front() {
                g.taken += 1;
                self.shared.cv.notify_all();
                return Ok(v);
            }
            if g.senders == 0 {
                return Err(RecvError);
            }
            g = self.shared.cv.wait(g).map_err(|_| RecvError)?;
        }
    }

    pub fn try_recv(&self) -> std::result::Result<T, TryRecvError> {
        let mut g = self.shared.m.lock().map_err(|_| TryRecvError::Disconnected)?;
        if let Some(v) = g.q.pop_front() {
            g.taken += 1;
            self.shared.cv.notify_all();
            return Ok(v);
        }
        if g.senders == 0 {
            return Err(TryRecvError::Disconnected);
        }
        Err(TryRecvError::Empty)
    }
}

impl<T> Drop for Sender<T> {
    fn drop(&mut self) {
        if let Ok(mut g) = self.shared.m.lock() {
            g.senders -= 1;
            if g.senders == 0 {
                self.shared.cv.notify_all();
            }
        }
    }
}

impl<T> Drop for Receiver<T> {
    fn drop(&mut self) {
        if let Ok(mut g) = self.shared.m.lock() {
            g.receiver_alive = false;
            self.shared.cv.notify_all();
        }
    }
}

#[cfg(all(test, not(loom)))]
mod tests {
    use super::*;
    use std::time::Duration;

    #[test]
    fn transport_shim_channel_buffers_then_blocks() {
        let (tx, rx) = sync_channel::<u32>(1);
        tx.send(1).unwrap();
        let h = std::thread::spawn(move || {
            tx.send(2).unwrap(); // blocks until the receiver takes 1
            tx
        });
        std::thread::sleep(Duration::from_millis(20));
        assert_eq!(rx.recv().unwrap(), 1);
        let tx = h.join().unwrap();
        assert_eq!(rx.recv().unwrap(), 2);
        drop(tx);
        assert_eq!(rx.recv(), Err(RecvError));
    }

    #[test]
    fn transport_shim_channel_rendezvous_waits_for_the_take() {
        let (tx, rx) = sync_channel::<u32>(0);
        let h = std::thread::spawn(move || {
            tx.send(7).unwrap();
            tx.send(8).unwrap();
        });
        assert_eq!(rx.recv().unwrap(), 7);
        assert_eq!(rx.recv().unwrap(), 8);
        h.join().unwrap();
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));
    }

    #[test]
    fn transport_shim_channel_dead_receiver_fails_blocked_sender() {
        let (tx, rx) = sync_channel::<u32>(1);
        tx.send(1).unwrap();
        let h = std::thread::spawn(move || tx.send(2)); // blocked: queue full
        std::thread::sleep(Duration::from_millis(20));
        drop(rx);
        assert_eq!(h.join().unwrap(), Err(SendError), "sender must unblock with an error");
    }

    #[test]
    fn transport_shim_channel_drains_after_sender_drop() {
        let (tx, rx) = sync_channel::<u32>(2);
        tx.send(1).unwrap();
        tx.send(2).unwrap();
        drop(tx);
        assert_eq!(rx.try_recv().unwrap(), 1);
        assert_eq!(rx.recv().unwrap(), 2);
        assert_eq!(rx.try_recv(), Err(TryRecvError::Disconnected));
    }
}
