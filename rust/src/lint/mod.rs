//! `galaxy lint` — the repo's invariant checker.
//!
//! Several of this codebase's load-bearing invariants are *textual*: they
//! say "this token may only appear in that module", which no type system
//! enforces. They used to live as `include_str!` grep pins inside
//! `tests/api_surface.rs`; this module promotes them into a first-class
//! lint pass with a declarative rule table, real `file:line` diagnostics,
//! and an inline allowlist. The `galaxy lint` CLI subcommand and the
//! `api_surface` integration test are both thin wrappers over [`RULES`].
//!
//! The scanner is deliberately *not* a Rust parser: it tokenizes just far
//! enough to strip comments, string/char literals, and `#[cfg(test)]`
//! module bodies, then substring-matches the rule table against what is
//! left. That keeps the checker dependency-free (no rustc plugin, no
//! syn), fast, and — because every rule is a plain token — trivially
//! auditable. Each rule documents *why* in [`Rule::why`]; the full
//! catalogue with allowlisting instructions lives in
//! `docs/INVARIANTS.md`.
//!
//! # Allowlisting
//!
//! A violation that is intentional is suppressed by a comment on (or
//! directly above) the flagged line:
//!
//! ```text
//! // lint: allow(rule-id): one-line justification
//! ```
//!
//! The marker covers its own line and, when it sits on a pure comment
//! line, extends through the next line that carries code — so a
//! multi-line justification comment block protects exactly the statement
//! it precedes. `galaxy lint --fix-allowlist` prints a paste-ready stanza
//! for every current violation.

use crate::error::{GalaxyError, Result};
use std::collections::BTreeMap;
use std::ffi::OsStr;
use std::fmt;
use std::fs;
use std::path::{Path, PathBuf};

/// One declarative invariant: forbid tokens in a path scope, require
/// pins elsewhere. All paths are `/`-separated and relative to the
/// source root (`rust/src`); a trailing `/` scopes a whole module tree.
pub struct Rule {
    /// Stable id, referenced by `lint: allow(<id>)` markers.
    pub id: &'static str,
    /// Why the invariant exists (shown in diagnostics).
    pub why: &'static str,
    /// Path prefixes this rule scans. Empty means every file.
    pub scan: &'static [&'static str],
    /// Path prefixes exempt from the forbid tokens.
    pub except: &'static [&'static str],
    /// Tokens that must not appear in scanned, non-exempt code.
    pub forbid: &'static [&'static str],
    /// Skip `#[cfg(test)]` / `#[cfg(all(test, ..))]` item bodies.
    pub skip_test_code: bool,
    /// `(file, token)` pins that must be present — the positive half of
    /// the invariant (the blessed definition/consultation sites).
    pub require: &'static [(&'static str, &'static str)],
}

/// The rule table. Every entry subsumes a pin that previously lived in
/// `tests/api_surface.rs` or a review checklist; see `docs/INVARIANTS.md`
/// for the catalogue (origin PR, rationale, allowlisting).
pub const RULES: &[Rule] = &[
    Rule {
        id: "partition-truth",
        why: "the §III-C.2 sequence split is planner truth; engines consult the \
              Deployment instead of re-deriving it (baselines simulate *other* \
              systems' strategies and are exempt)",
        scan: &[],
        except: &["planner/", "baselines/"],
        forbid: &["equal_seq_partition"],
        skip_test_code: false,
        require: &[
            ("planner/mod.rs", "pub fn equal_seq_partition"),
            ("planner/deployment.rs", "equal_seq_partition"),
        ],
    },
    Rule {
        id: "bucket-geom",
        why: "BucketGeom must derive tile geometry from the Deployment, not a \
              private equal split",
        scan: &["cluster/mod.rs"],
        except: &[],
        forbid: &["fn equal("],
        skip_test_code: false,
        require: &[("cluster/mod.rs", "fn from_deployment")],
    },
    Rule {
        id: "transport-sync-shim",
        why: "transport code must go through transport::sync so the loom model \
              checks the exact synchronization the real build runs",
        scan: &["transport/"],
        except: &["transport/sync.rs"],
        forbid: &["std::sync", "std::thread", "std::time"],
        skip_test_code: true,
        require: &[
            ("transport/mod.rs", "use self::sync::"),
            ("transport/wire.rs", "use super::sync::"),
        ],
    },
    Rule {
        id: "no-unwrap",
        why: "library code propagates GalaxyError; a panic in an io-thread \
              poisons locks instead of degrading like a dead neighbor",
        scan: &[],
        except: &[],
        forbid: &[".unwrap()", ".expect("],
        skip_test_code: true,
        require: &[],
    },
    Rule {
        id: "wire-elem-bytes",
        why: "ring-byte accounting must follow WireFormat::elem_bytes so \
              quantized formats shrink modeled and measured bytes alike",
        scan: &[],
        except: &["sim/net.rs"],
        forbid: &["WIRE_BYTES_PER_ELEM"],
        skip_test_code: true,
        require: &[
            ("sim/engine.rs", "elem_bytes"),
            ("baselines/mod.rs", "elem_bytes"),
            ("baselines/pipeline.rs", "elem_bytes"),
            ("cli.rs", "elem_bytes"),
        ],
    },
    Rule {
        id: "tile-grain-truth",
        why: "the overlap micro-tile grain T is a planned per-rung quantity: only \
              the planner selects it (Deployment::choose_tile_grains / \
              set_tile_grain); engines and clusters consult tile_grain_for",
        scan: &[],
        except: &["planner/"],
        forbid: &[".tile_grain ="],
        skip_test_code: true,
        require: &[
            ("planner/deployment.rs", "pub fn choose_tile_grains"),
            ("planner/deployment.rs", "pub fn set_tile_grain"),
            ("sim/engine.rs", "tile_grain_for"),
            ("cluster/mod.rs", "tile_grain_for"),
        ],
    },
    Rule {
        id: "kv-partition-truth",
        why: "KV-cache shard layouts are derived from the rung's head partition \
              (Deployment::partition_for) via KvLayout::for_rung; hand-built \
              KvShardSpec maps outside kvcache/ would fork partition truth",
        scan: &[],
        except: &["kvcache/"],
        forbid: &["KvShardSpec {"],
        skip_test_code: true,
        require: &[
            ("kvcache/mod.rs", "partition_for"),
            ("kvcache/mod.rs", "pub fn for_rung"),
            ("sim/engine.rs", "for_rung"),
        ],
    },
    Rule {
        id: "measured-clock",
        why: "wall-clock reads outside the measurement plumbing make replans \
              depend on un-modeled time; route timing through the cluster's \
              measured path (Engine::measured_now_s)",
        scan: &[],
        except: &[
            "cluster/local.rs",
            "cluster/mod.rs",
            "cluster/worker.rs",
            "profiler/real.rs",
            "transport/sync.rs",
        ],
        forbid: &["Instant::now", "SystemTime::now"],
        skip_test_code: true,
        require: &[("engine/mod.rs", "measured_now_s")],
    },
];

/// A single lint diagnostic. `line == 0` marks a file-level violation
/// (a missing require-pin).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Violation {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}:{}: [{}] {}", self.file, self.line, self.rule, self.message)
    }
}

/// A scanned source file: comment/string-stripped text (newlines
/// preserved, so line numbers survive), per-line allow markers, and the
/// `#[cfg(test)]`-body mask.
pub struct FileScan {
    /// Whole stripped text (for require-pin checks).
    pub stripped: String,
    /// Stripped text split into lines (no trailing newline per entry).
    pub lines: Vec<String>,
    /// 1-based line -> rule ids allowed there via `lint: allow(..)`.
    pub allows: BTreeMap<usize, Vec<String>>,
    /// `mask[i]` is true when 1-based line `i + 1` is inside a
    /// `#[cfg(test)]`-gated item body.
    pub test_mask: Vec<bool>,
}

/// Strip comments (line and nested block), string literals (plain, raw,
/// byte), and char literals from Rust source, replacing them with spaces
/// and preserving every newline. Lifetimes (`'a`) survive; `'x'` char
/// literals do not — the lookahead distinguishes them.
pub fn strip_code(src: &str) -> String {
    let chars: Vec<char> = src.chars().collect();
    let mut out = String::with_capacity(src.len());
    let n = chars.len();
    let mut i = 0;

    // Emit a blank for a stripped char, preserving newlines.
    fn blank(out: &mut String, c: char) {
        out.push(if c == '\n' { '\n' } else { ' ' });
    }

    while i < n {
        let c = chars[i];
        // Line comment.
        if c == '/' && i + 1 < n && chars[i + 1] == '/' {
            while i < n && chars[i] != '\n' {
                out.push(' ');
                i += 1;
            }
            continue;
        }
        // Block comment (nested).
        if c == '/' && i + 1 < n && chars[i + 1] == '*' {
            let mut depth = 0usize;
            while i < n {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    blank(&mut out, chars[i]);
                    blank(&mut out, chars[i + 1]);
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    blank(&mut out, chars[i]);
                    blank(&mut out, chars[i + 1]);
                    i += 2;
                    if depth == 0 {
                        break;
                    }
                } else {
                    blank(&mut out, chars[i]);
                    i += 1;
                }
            }
            continue;
        }
        // Raw (and raw byte) string: r"..", r#".."#, br#".."#.
        let ident_before = i > 0 && (chars[i - 1].is_alphanumeric() || chars[i - 1] == '_');
        if (c == 'r' || c == 'b') && !ident_before {
            let start = if c == 'b' && i + 1 < n && chars[i + 1] == 'r' { i + 2 } else { i + 1 };
            let is_raw = c == 'r' || (c == 'b' && start == i + 2);
            let mut hashes = 0usize;
            let mut j = start;
            while is_raw && j < n && chars[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if is_raw && j < n && chars[j] == '"' {
                // Keep the prefix chars blanked, scan to `"` + hashes `#`s.
                for k in i..=j {
                    blank(&mut out, chars[k]);
                }
                i = j + 1;
                while i < n {
                    let closes = chars[i] == '"'
                        && (1..=hashes).all(|k| chars.get(i + k) == Some(&'#'));
                    if closes {
                        for k in i..(i + 1 + hashes).min(n) {
                            blank(&mut out, chars[k]);
                        }
                        i += 1 + hashes;
                        break;
                    }
                    blank(&mut out, chars[i]);
                    i += 1;
                }
                continue;
            }
            // Not a raw string: fall through and emit `r`/`b` literally
            // (a following `"` is handled as a plain string next round).
        }
        // Plain (or byte) string literal.
        if c == '"' {
            blank(&mut out, c);
            i += 1;
            while i < n {
                if chars[i] == '\\' && i + 1 < n {
                    blank(&mut out, chars[i]);
                    blank(&mut out, chars[i + 1]);
                    i += 2;
                    continue;
                }
                let done = chars[i] == '"';
                blank(&mut out, chars[i]);
                i += 1;
                if done {
                    break;
                }
            }
            continue;
        }
        // Char literal vs lifetime: after `'`, a backslash or a
        // char-then-`'` means char literal; anything else is a lifetime.
        if c == '\'' {
            let next = chars.get(i + 1).copied();
            let after = chars.get(i + 2).copied();
            let is_char = matches!(next, Some('\\')) || matches!(after, Some('\''));
            if is_char {
                blank(&mut out, c);
                i += 1;
                while i < n {
                    if chars[i] == '\\' && i + 1 < n {
                        blank(&mut out, chars[i]);
                        blank(&mut out, chars[i + 1]);
                        i += 2;
                        continue;
                    }
                    let done = chars[i] == '\'';
                    blank(&mut out, chars[i]);
                    i += 1;
                    if done {
                        break;
                    }
                }
                continue;
            }
        }
        out.push(c);
        i += 1;
    }
    out
}

/// Collect `lint: allow(rule-id)` markers from the *raw* source (they
/// live in comments, which stripping removes). Returns 1-based marker
/// line -> rule ids on that line.
pub fn inline_allows(src: &str) -> BTreeMap<usize, Vec<String>> {
    let mut out: BTreeMap<usize, Vec<String>> = BTreeMap::new();
    for (idx, raw) in src.lines().enumerate() {
        let mut rest = raw;
        while let Some(pos) = rest.find("lint: allow(") {
            rest = &rest[pos + "lint: allow(".len()..];
            if let Some(end) = rest.find(')') {
                out.entry(idx + 1).or_default().push(rest[..end].to_string());
                rest = &rest[end..];
            } else {
                break;
            }
        }
    }
    out
}

/// Mark every line inside a `#[cfg(test)]` / `#[cfg(all(test, ..))]`
/// gated item body, by brace counting on stripped lines.
fn test_line_mask(lines: &[String]) -> Vec<bool> {
    let n = lines.len();
    let mut mask = vec![false; n];
    let mut i = 0;
    while i < n {
        let l = &lines[i];
        if !(l.contains("#[cfg(test)") || l.contains("#[cfg(all(test")) {
            i += 1;
            continue;
        }
        // Walk forward over the gated item: everything through its
        // closing brace (or terminating `;` for a brace-less item).
        let mut depth = 0usize;
        let mut started = false;
        let mut j = i;
        'item: while j < n {
            mask[j] = true;
            for ch in lines[j].chars() {
                match ch {
                    '{' => {
                        depth += 1;
                        started = true;
                    }
                    '}' => {
                        depth = depth.saturating_sub(1);
                        if started && depth == 0 {
                            break 'item;
                        }
                    }
                    ';' if !started => break 'item,
                    _ => {}
                }
            }
            j += 1;
        }
        i = j + 1;
    }
    mask
}

/// Run the full scanner over one file's source.
pub fn scan_source(src: &str) -> FileScan {
    let stripped = strip_code(src);
    let lines: Vec<String> = stripped.lines().map(str::to_string).collect();
    // Expand each allow marker: it covers its own line and, when that
    // line holds no code, extends through the next code-bearing line.
    let mut allows: BTreeMap<usize, Vec<String>> = BTreeMap::new();
    for (marker, ids) in inline_allows(src) {
        let mut l = marker;
        allows.entry(l).or_default().extend(ids.iter().cloned());
        while l <= lines.len() && lines[l - 1].trim().is_empty() {
            l += 1;
            allows.entry(l).or_default().extend(ids.iter().cloned());
        }
    }
    let test_mask = test_line_mask(&lines);
    FileScan { stripped, lines, allows, test_mask }
}

fn in_scope(rule: &Rule, rel: &str) -> bool {
    rule.scan.is_empty() || rule.scan.iter().any(|p| rel.starts_with(p))
}

fn exempt(rule: &Rule, rel: &str) -> bool {
    rule.except.iter().any(|p| rel.starts_with(p))
}

/// Apply every in-scope rule's forbid tokens to one scanned file.
/// Require-pins are directory-level and checked by [`check_dir`].
pub fn check_source(rel: &str, src: &str) -> Vec<Violation> {
    let scan = scan_source(src);
    let mut out = Vec::new();
    for rule in RULES {
        if !in_scope(rule, rel) || exempt(rule, rel) {
            continue;
        }
        for (idx, line) in scan.lines.iter().enumerate() {
            let lineno = idx + 1;
            if rule.skip_test_code && scan.test_mask[idx] {
                continue;
            }
            let allowed = scan
                .allows
                .get(&lineno)
                .map_or(false, |ids| ids.iter().any(|id| id == rule.id));
            if allowed {
                continue;
            }
            for token in rule.forbid {
                if line.contains(token) {
                    out.push(Violation {
                        file: rel.to_string(),
                        line: lineno,
                        rule: rule.id,
                        message: format!("forbidden token `{token}`: {}", rule.why),
                    });
                }
            }
        }
    }
    out
}

/// Locate the crate source root: `rust/src` from the repo root, `src`
/// from inside the crate (integration tests run there).
pub fn src_root() -> Result<PathBuf> {
    for cand in ["rust/src", "src"] {
        let p = Path::new(cand);
        if p.is_dir() {
            return Ok(p.to_path_buf());
        }
    }
    Err(GalaxyError::MissingArtifact(
        "cannot locate the crate source root (run `galaxy lint` from the repo root)".into(),
    ))
}

/// Deterministic (sorted) recursive walk of `.rs` files under `root`.
fn rs_files(root: &Path) -> Result<Vec<PathBuf>> {
    let mut out = Vec::new();
    let mut stack = vec![root.to_path_buf()];
    while let Some(dir) = stack.pop() {
        for entry in fs::read_dir(&dir)? {
            let path = entry?.path();
            if path.is_dir() {
                stack.push(path);
            } else if path.extension() == Some(OsStr::new("rs")) {
                out.push(path);
            }
        }
    }
    out.sort();
    Ok(out)
}

/// Lint every `.rs` file under `root` against [`RULES`], including the
/// directory-level require-pins. Violations come back sorted by
/// `(file, line, rule)`; empty means the tree is clean.
pub fn check_dir(root: &Path) -> Result<Vec<Violation>> {
    let mut sources: BTreeMap<String, String> = BTreeMap::new();
    for path in rs_files(root)? {
        let rel = path
            .strip_prefix(root)
            .unwrap_or(&path)
            .to_string_lossy()
            .replace('\\', "/");
        sources.insert(rel, fs::read_to_string(&path)?);
    }
    let mut out = Vec::new();
    for (rel, src) in &sources {
        out.extend(check_source(rel, src));
    }
    for rule in RULES {
        for (file, token) in rule.require {
            let present =
                sources.get(*file).map(|src| strip_code(src).contains(token)).unwrap_or(false);
            if !present {
                out.push(Violation {
                    file: (*file).to_string(),
                    line: 0,
                    rule: rule.id,
                    message: format!("required pin `{token}` is missing: {}", rule.why),
                });
            }
        }
    }
    out.sort_by(|a, b| (&a.file, a.line, a.rule).cmp(&(&b.file, b.line, b.rule)));
    Ok(out)
}

/// Lint the crate from wherever we are (CLI and test entry point).
pub fn check() -> Result<Vec<Violation>> {
    check_dir(&src_root()?)
}

/// A paste-ready allowlist stanza for every line-level violation —
/// `galaxy lint --fix-allowlist`.
pub fn fix_allowlist(violations: &[Violation]) -> String {
    let mut out = String::new();
    for v in violations.iter().filter(|v| v.line > 0) {
        out.push_str(&format!(
            "{}:{}: insert above the flagged line:\n    \
             // lint: allow({}): <why this site is exempt>\n",
            v.file, v.line, v.rule
        ));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strip_removes_comments_and_strings_preserving_lines() {
        let src = concat!(
            "let a = 1; // trailing .unwrap()\n",
            "/* block\n",
            ".expect( */\n",
            "let b = \"x.unwrap()\";\n"
        );
        let s = strip_code(src);
        assert_eq!(s.lines().count(), src.lines().count());
        assert!(!s.contains(".unwrap()"));
        assert!(!s.contains(".expect("));
        assert!(s.contains("let a = 1;"));
        assert!(s.contains("let b ="));
    }

    #[test]
    fn strip_handles_raw_strings_chars_and_lifetimes() {
        let src = concat!(
            "let r = r#\"contains .unwrap() here\"#;\n",
            "fn f<'a>(x: &'a str) -> char { '\\'' }\n",
            "let q = 'u';\n"
        );
        let s = strip_code(src);
        assert!(!s.contains(".unwrap()"), "raw string not stripped: {s}");
        assert!(s.contains("fn f<'a>(x: &'a str)"), "lifetimes must survive: {s}");
        assert!(!s.contains("'u'"), "char literal must be stripped: {s}");
    }

    #[test]
    fn nested_block_comments_strip_fully() {
        let src = "/* outer /* inner .unwrap() */ still comment */ let x = 2;\n";
        let s = strip_code(src);
        assert!(!s.contains(".unwrap()"));
        assert!(s.contains("let x = 2;"));
    }

    #[test]
    fn allow_marker_covers_the_next_code_line() {
        let src = concat!(
            "// lint: allow(no-unwrap): justified\n",
            "// continues\n",
            "v.last().expect(\"ok\");\n",
            "v.first().expect(\"not ok\");\n"
        );
        let v = check_source("metrics/mod.rs", src);
        let unwraps: Vec<_> = v.iter().filter(|v| v.rule == "no-unwrap").collect();
        assert_eq!(unwraps.len(), 1, "{unwraps:?}");
        assert_eq!(unwraps[0].line, 4);
    }

    #[test]
    fn cfg_test_bodies_are_skipped_for_skip_test_rules() {
        let src = "fn lib() {}\n#[cfg(test)]\nmod tests {\n    fn t() { x.unwrap(); }\n}\n";
        let v = check_source("metrics/mod.rs", src);
        assert!(v.iter().all(|v| v.rule != "no-unwrap"), "{v:?}");
        // ...but a library-code unwrap on the same file still fires.
        let src2 = "fn lib(x: Option<u8>) { x.unwrap(); }\n";
        let v2 = check_source("metrics/mod.rs", src2);
        assert!(v2.iter().any(|v| v.rule == "no-unwrap"));
    }

    #[test]
    fn partition_truth_fires_outside_the_planner_only() {
        let src = "fn f() { let p = equal_seq_partition(8, 2); }\n";
        let out = check_source("engine/mod.rs", src);
        assert!(out.iter().any(|v| v.rule == "partition-truth" && v.line == 1));
        assert!(check_source("planner/mod.rs", src).iter().all(|v| v.rule != "partition-truth"));
        assert!(check_source("baselines/mod.rs", src).iter().all(|v| v.rule != "partition-truth"));
    }

    #[test]
    fn transport_sync_shim_scopes_to_transport_tree() {
        let src = "use std::sync::Mutex;\n";
        assert!(check_source("transport/mod.rs", src)
            .iter()
            .any(|v| v.rule == "transport-sync-shim"));
        assert!(check_source("transport/sync.rs", src)
            .iter()
            .all(|v| v.rule != "transport-sync-shim"));
        assert!(check_source("serving/mod.rs", src)
            .iter()
            .all(|v| v.rule != "transport-sync-shim"));
    }

    #[test]
    fn tile_grain_truth_pins_selection_to_the_planner() {
        let src = "fn f(g: &mut BucketGeom) { g.tile_grain = 8; }\n";
        assert!(check_source("cluster/mod.rs", src)
            .iter()
            .any(|v| v.rule == "tile-grain-truth"));
        assert!(check_source("planner/deployment.rs", src)
            .iter()
            .all(|v| v.rule != "tile-grain-truth"));
    }

    #[test]
    fn fix_allowlist_emits_a_stanza_per_line_violation() {
        let v = check_source("engine/mod.rs", "let t = Instant::now();\n");
        assert!(v.iter().any(|v| v.rule == "measured-clock"));
        let stanza = fix_allowlist(&v);
        assert!(stanza.contains("lint: allow(measured-clock)"), "{stanza}");
        assert!(stanza.contains("engine/mod.rs:1"), "{stanza}");
    }

    #[test]
    fn the_tree_is_clean() {
        // The repo's own sources must pass the lint — the same check the
        // CLI and CI run. Root resolution handles both unit-test (crate
        // dir) and repo-root working directories.
        let violations = check().expect("lint walk");
        assert!(
            violations.is_empty(),
            "lint violations:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }
}
