//! Ring collectives over sequence-partitioned activations.
//!
//! HMP needs exactly two primitives (paper §III-B.4): **ReduceScatter** at
//! the end of every TP block and **AllGather** at the end of every SP
//! block. A Ring-AllReduce (what Megatron-LM uses) is provided for the
//! baseline; by the standard identity its volume equals RS followed by AG
//! (paper cites Horovod [27]) — asserted by a test below.
//!
//! Two layers of implementation:
//! * [`reference`] — naive direct computations, the semantic ground truth.
//! * [`lockstep`] — step-by-step ring execution driven by the overlap
//!   schedules in [`crate::parallel::overlap`], exercising the exact
//!   send/recv/reduce dance the real worker threads perform. Property
//!   tests assert lockstep == reference for arbitrary device counts and
//!   partitions; the threaded cluster reuses the same step plans.

use crate::error::{GalaxyError, Result};
use crate::parallel::overlap::{all_gather_steps, reduce_scatter_steps};
use crate::tensor::Tensor2;

/// Naive reference implementations (ground truth).
pub mod reference {
    use super::*;

    /// AllGather: concatenate per-device row shards; every device gets the
    /// full tensor.
    pub fn all_gather(shards: &[Tensor2]) -> Result<Tensor2> {
        Tensor2::concat_rows(shards)
    }

    /// ReduceScatter: element-wise sum the per-device partials, then split
    /// the sum into row shards of sizes `seq_parts`.
    pub fn reduce_scatter(partials: &[Tensor2], seq_parts: &[usize]) -> Result<Vec<Tensor2>> {
        let mut sum = partials
            .first()
            .ok_or_else(|| GalaxyError::Shape("reduce_scatter: empty".into()))?
            .clone();
        for p in &partials[1..] {
            sum.add_assign(p)?;
        }
        let mut out = Vec::with_capacity(seq_parts.len());
        let mut row = 0;
        for &rows in seq_parts {
            out.push(sum.slice_rows(row, rows)?);
            row += rows;
        }
        Ok(out)
    }

    /// AllReduce: every device ends with the element-wise sum.
    pub fn all_reduce(partials: &[Tensor2]) -> Result<Tensor2> {
        let mut sum = partials
            .first()
            .ok_or_else(|| GalaxyError::Shape("all_reduce: empty".into()))?
            .clone();
        for p in &partials[1..] {
            sum.add_assign(p)?;
        }
        Ok(sum)
    }
}

/// Bytes a ring AllGather moves per device: (D-1) steps × shard bytes.
pub fn ag_bytes_per_device(shard_bytes: u64, d: usize) -> u64 {
    shard_bytes * (d as u64 - 1)
}

/// Bytes a ring ReduceScatter moves per device.
pub fn rs_bytes_per_device(chunk_bytes: u64, d: usize) -> u64 {
    chunk_bytes * (d as u64 - 1)
}

/// Ring-AllGather executed in lockstep across all devices, following the
/// per-device step schedules of [`all_gather_steps`]. `shards[r]` is the
/// row-tile owned by device `r`; returns, per device, the gathered tiles
/// in slot order (equal to the reference concat for every device).
pub fn ring_all_gather(shards: &[Tensor2]) -> Result<Vec<Tensor2>> {
    let d = shards.len();
    if d == 0 {
        return Err(GalaxyError::Shape("ring_all_gather: empty".into()));
    }
    // tiles[i][r] = Some(tile r) once device i holds it.
    let mut tiles: Vec<Vec<Option<Tensor2>>> = (0..d)
        .map(|i| {
            (0..d)
                .map(|r| if r == i { Some(shards[r].clone()) } else { None })
                .collect()
        })
        .collect();
    let plans: Vec<_> = (0..d).map(|i| all_gather_steps(i, d)).collect();
    for s in 0..d {
        // Gather the wire traffic for this step first (lockstep barrier),
        // then deliver — models simultaneous full-duplex sends.
        let mut deliveries: Vec<(usize, usize, Tensor2)> = Vec::new();
        for i in 0..d {
            if let Some(t) = plans[i][s].send_tile {
                let payload = tiles[i][t]
                    .clone()
                    .ok_or_else(|| GalaxyError::Fabric(format!("dev {i} step {s}: tile {t} not yet held")))?;
                deliveries.push(((i + 1) % d, t, payload));
            }
        }
        for (to, t, payload) in deliveries {
            tiles[to][t] = Some(payload);
        }
        // (compute_tile is where the engine would run the entry GEMM.)
        for (i, plan) in plans.iter().enumerate() {
            let ct = plan[s].compute_tile;
            if tiles[i][ct].is_none() {
                return Err(GalaxyError::Fabric(format!(
                    "dev {i} step {s}: compute tile {ct} missing — schedule broken"
                )));
            }
        }
    }
    (0..d)
        .map(|i| {
            let parts: Vec<Tensor2> = (0..d).map(|r| tiles[i][r].take().unwrap()).collect();
            Tensor2::concat_rows(&parts)
        })
        .collect()
}

/// Ring-ReduceScatter executed in lockstep, following
/// [`reduce_scatter_steps`]. `partials[i]` is device i's full `[seq, h]`
/// partial; `seq_parts` the row-tile sizes. Returns, per device, its fully
/// reduced tile (device i gets tile i).
pub fn ring_reduce_scatter(partials: &[Tensor2], seq_parts: &[usize]) -> Result<Vec<Tensor2>> {
    let d = partials.len();
    if d == 0 || seq_parts.len() != d {
        return Err(GalaxyError::Shape(format!(
            "ring_reduce_scatter: {d} devices vs {} parts",
            seq_parts.len()
        )));
    }
    let offsets: Vec<usize> = (0..d).map(|r| seq_parts[..r].iter().sum()).collect();
    let tile_of = |i: usize, r: usize| -> Result<Tensor2> {
        partials[i].slice_rows(offsets[r], seq_parts[r])
    };
    let plans: Vec<_> = (0..d).map(|i| reduce_scatter_steps(i, d)).collect();
    // acc[i] = the partial-sum tile device i accumulated in its last step.
    let mut acc: Vec<Option<Tensor2>> = vec![None; d];
    for s in 0..d {
        // Each device computes its step's GEMM-output tile (here: slices
        // its own partial — the engine plugs real GEMMs in).
        let mut computed: Vec<Tensor2> = Vec::with_capacity(d);
        for (i, plan) in plans.iter().enumerate() {
            computed.push(tile_of(i, plan[s].compute_tile)?);
        }
        // Wire: forward last step's accumulation, reduce-add into computed.
        let sends: Vec<Option<Tensor2>> = (0..d)
            .map(|i| plans[i][s].send_tile.map(|_| acc[i].clone().expect("acc present")))
            .collect();
        for i in 0..d {
            let mut mine = computed[i].clone();
            if plans[i][s].recv_tile.is_some() {
                let from = (i + d - 1) % d;
                let payload = sends[from]
                    .clone()
                    .ok_or_else(|| GalaxyError::Fabric(format!("dev {from} had nothing to send at step {s}")))?;
                mine.add_assign(&payload)?;
            }
            acc[i] = Some(mine);
        }
    }
    Ok(acc.into_iter().map(|a| a.unwrap()).collect())
}

/// Ring-AllReduce = Ring-ReduceScatter + Ring-AllGather (the Megatron-LM
/// baseline synchronization; paper §III-B.5 merit 2).
pub fn ring_all_reduce(partials: &[Tensor2], seq_parts: &[usize]) -> Result<Vec<Tensor2>> {
    let scattered = ring_reduce_scatter(partials, seq_parts)?;
    ring_all_gather(&scattered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Pcg64};

    fn rand_tensor(rng: &mut Pcg64, rows: usize, cols: usize) -> Tensor2 {
        Tensor2::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal()).collect()).unwrap()
    }

    #[test]
    fn ring_ag_matches_reference_equal_parts() {
        let mut rng = Pcg64::new(1);
        for d in 1..=5 {
            let shards: Vec<Tensor2> = (0..d).map(|_| rand_tensor(&mut rng, 4, 6)).collect();
            let want = reference::all_gather(&shards).unwrap();
            for got in ring_all_gather(&shards).unwrap() {
                assert_eq!(got, want, "d={d}");
            }
        }
    }

    #[test]
    fn ring_ag_unequal_parts() {
        let mut rng = Pcg64::new(2);
        let shards = vec![
            rand_tensor(&mut rng, 5, 3),
            rand_tensor(&mut rng, 2, 3),
            rand_tensor(&mut rng, 7, 3),
        ];
        let want = reference::all_gather(&shards).unwrap();
        for got in ring_all_gather(&shards).unwrap() {
            assert_eq!(got, want);
        }
    }

    #[test]
    fn ring_rs_matches_reference() {
        let mut rng = Pcg64::new(3);
        for d in 1..=5 {
            let parts: Vec<usize> = (0..d).map(|r| 2 + r).collect();
            let seq: usize = parts.iter().sum();
            let partials: Vec<Tensor2> = (0..d).map(|_| rand_tensor(&mut rng, seq, 4)).collect();
            let want = reference::reduce_scatter(&partials, &parts).unwrap();
            let got = ring_reduce_scatter(&partials, &parts).unwrap();
            for (g, w) in got.iter().zip(want.iter()) {
                assert!(g.allclose(w, 1e-5, 1e-5), "d={d}");
            }
        }
    }

    #[test]
    fn ring_all_reduce_matches_reference() {
        let mut rng = Pcg64::new(4);
        let d = 3;
        let parts = vec![3usize, 3, 2];
        let partials: Vec<Tensor2> = (0..d).map(|_| rand_tensor(&mut rng, 8, 5)).collect();
        let want = reference::all_reduce(&partials).unwrap();
        for got in ring_all_reduce(&partials, &parts).unwrap() {
            assert!(got.allclose(&want, 1e-5, 1e-5));
        }
    }

    #[test]
    fn allreduce_volume_identity() {
        // Paper §III-B.5: Ring-AllReduce volume == Ring-RS + Ring-AG.
        // AllReduce classic volume per device: 2*(D-1)/D * N bytes; our RS
        // and AG helpers each move (D-1)*chunk where chunk = N/D.
        let n_bytes = 1_000_000u64;
        for d in 2..=6 {
            let chunk = n_bytes / d as u64;
            let rs_ag = rs_bytes_per_device(chunk, d) + ag_bytes_per_device(chunk, d);
            let allreduce = 2 * (d as u64 - 1) * chunk;
            assert_eq!(rs_ag, allreduce, "d={d}");
        }
    }

    #[test]
    fn empty_inputs_error() {
        assert!(ring_all_gather(&[]).is_err());
        assert!(ring_reduce_scatter(&[], &[]).is_err());
        assert!(reference::all_reduce(&[]).is_err());
    }

    #[test]
    fn prop_ring_ag_equals_reference() {
        forall(
            "ring_ag==naive_ag",
            7,
            60,
            |rng| {
                let d = rng.range(1, 6) as usize;
                let cols = rng.range(1, 8) as usize;
                let shards: Vec<Tensor2> = (0..d)
                    .map(|_| {
                        let rows = rng.range(1, 6) as usize;
                        rand_tensor(rng, rows, cols)
                    })
                    .collect();
                shards
            },
            |shards| {
                let want = reference::all_gather(shards).map_err(|e| e.to_string())?;
                let got = ring_all_gather(shards).map_err(|e| e.to_string())?;
                for g in got {
                    if g != want {
                        return Err("mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn prop_ring_rs_equals_reference() {
        forall(
            "ring_rs==naive_rs",
            8,
            60,
            |rng| {
                let d = rng.range(1, 6) as usize;
                let cols = rng.range(1, 8) as usize;
                let parts: Vec<usize> = (0..d).map(|_| rng.range(1, 5) as usize).collect();
                let seq: usize = parts.iter().sum();
                let partials: Vec<Tensor2> =
                    (0..d).map(|_| rand_tensor(rng, seq, cols)).collect();
                (partials, parts)
            },
            |(partials, parts)| {
                let want = reference::reduce_scatter(partials, parts).map_err(|e| e.to_string())?;
                let got = ring_reduce_scatter(partials, parts).map_err(|e| e.to_string())?;
                for (g, w) in got.iter().zip(want.iter()) {
                    if !g.allclose(w, 1e-4, 1e-4) {
                        return Err(format!("diff {}", g.max_abs_diff(w).unwrap()));
                    }
                }
                Ok(())
            },
        );
    }
}
