//! Ring collectives over sequence-partitioned activations.
//!
//! HMP needs exactly two primitives (paper §III-B.4): **ReduceScatter** at
//! the end of every TP block and **AllGather** at the end of every SP
//! block. A Ring-AllReduce (what Megatron-LM uses) is provided for the
//! baseline; by the standard identity its volume equals RS followed by AG
//! (paper cites Horovod [27]) — asserted by a test below.
//!
//! Two layers of implementation:
//! * [`reference`] — naive direct computations, the semantic ground truth.
//! * lockstep — step-by-step ring execution driven by the overlap
//!   schedules in [`crate::parallel::overlap`], moving every tile through
//!   the in-process [`crate::transport::MemLink`] endpoints with the
//!   same double-buffered slot/backpressure contract the threaded
//!   cluster links enforce. Property tests assert lockstep ==
//!   reference for arbitrary device counts and partitions — including
//!   **interleaved multi-request traffic**, where two requests' tiles
//!   share each link's two slots ([`ring_all_gather_multi`] /
//!   [`ring_reduce_scatter_multi`]); a third concurrent request
//!   backpressures, which is exactly the transport contract.

use std::sync::Arc;

use crate::error::{GalaxyError, Result};
use crate::parallel::overlap::{
    all_gather_micro_steps, all_gather_steps, micro_rows, reduce_scatter_micro_steps,
    reduce_scatter_steps,
};
use crate::tensor::Tensor2;
use crate::transport::{mem_ring, take_tile, RingLink, TileCodec, WireFormat, LINK_SLOTS};

/// Naive reference implementations (ground truth).
pub mod reference {
    use super::*;

    /// AllGather: concatenate per-device row shards; every device gets the
    /// full tensor.
    pub fn all_gather(shards: &[Tensor2]) -> Result<Tensor2> {
        Tensor2::concat_rows(shards)
    }

    /// ReduceScatter: element-wise sum the per-device partials, then split
    /// the sum into row shards of sizes `seq_parts`.
    pub fn reduce_scatter(partials: &[Tensor2], seq_parts: &[usize]) -> Result<Vec<Tensor2>> {
        let mut sum = partials
            .first()
            .ok_or_else(|| GalaxyError::Shape("reduce_scatter: empty".into()))?
            .clone();
        for p in &partials[1..] {
            sum.add_assign(p)?;
        }
        let mut out = Vec::with_capacity(seq_parts.len());
        let mut row = 0;
        for &rows in seq_parts {
            out.push(sum.slice_rows(row, rows)?);
            row += rows;
        }
        Ok(out)
    }

    /// AllReduce: every device ends with the element-wise sum.
    pub fn all_reduce(partials: &[Tensor2]) -> Result<Tensor2> {
        let mut sum = partials
            .first()
            .ok_or_else(|| GalaxyError::Shape("all_reduce: empty".into()))?
            .clone();
        for p in &partials[1..] {
            sum.add_assign(p)?;
        }
        Ok(sum)
    }
}

/// Bytes a ring AllGather moves per device: (D-1) steps × shard bytes.
pub fn ag_bytes_per_device(shard_bytes: u64, d: usize) -> u64 {
    shard_bytes * (d as u64 - 1)
}

/// Bytes a ring ReduceScatter moves per device.
pub fn rs_bytes_per_device(chunk_bytes: u64, d: usize) -> u64 {
    chunk_bytes * (d as u64 - 1)
}

/// Ring-AllGather executed in lockstep across all devices, following the
/// per-device step schedules of [`all_gather_steps`]. `shards[r]` is the
/// row-tile owned by device `r`; returns, per device, the gathered tiles
/// in slot order (equal to the reference concat for every device).
pub fn ring_all_gather(shards: &[Tensor2]) -> Result<Vec<Tensor2>> {
    ring_all_gather_wire(shards, WireFormat::F32)
}

/// [`ring_all_gather`] with an explicit wire format: tiles are encoded on
/// post and decoded on completion, so lossy formats ([`WireFormat::F16`],
/// [`WireFormat::I8`]) bound-approximate the reference gather while
/// moving 2x/4x fewer bytes.
pub fn ring_all_gather_wire(shards: &[Tensor2], format: WireFormat) -> Result<Vec<Tensor2>> {
    let mut per_req = ring_all_gather_multi_wire(std::slice::from_ref(&shards.to_vec()), format)?;
    per_req
        .pop()
        .ok_or_else(|| GalaxyError::Fabric("ring_all_gather: one request in, none out".into()))
}

/// Lockstep Ring-AllGather for one or more **interleaved requests** over
/// one shared set of double-buffered in-process links — the transport
/// picture of the cluster's layer-granular request interleaving, where
/// consecutive requests' tiles ride the same links. Each round posts
/// every request's tile before any is consumed, so two requests occupy
/// exactly the [`LINK_SLOTS`] slots; a third errors with backpressure.
///
/// `requests[q][r]` is request `q`'s row-tile owned by device `r`.
/// Returns, per request, the per-device gathered tensors.
pub fn ring_all_gather_multi(requests: &[Vec<Tensor2>]) -> Result<Vec<Vec<Tensor2>>> {
    ring_all_gather_multi_wire(requests, WireFormat::F32)
}

/// [`ring_all_gather_multi`] with an explicit wire format (see
/// [`ring_all_gather_wire`]).
pub fn ring_all_gather_multi_wire(
    requests: &[Vec<Tensor2>],
    format: WireFormat,
) -> Result<Vec<Vec<Tensor2>>> {
    let d = requests.first().map(|r| r.len()).unwrap_or(0);
    if d == 0 {
        return Err(GalaxyError::Shape("ring_all_gather: empty".into()));
    }
    if requests.iter().any(|r| r.len() != d) {
        return Err(GalaxyError::Shape("ring_all_gather: uneven device counts".into()));
    }
    let nq = requests.len();
    let mut links = mem_ring(d, LINK_SLOTS);
    let codec = TileCodec::new(format);
    // tiles[q][i][r] = Some(tile r) once device i holds it for request q.
    // Refcounted: posting a held tile bumps the count, never copies f32s.
    let mut tiles: Vec<Vec<Vec<Option<Arc<Tensor2>>>>> = (0..nq)
        .map(|q| {
            (0..d)
                .map(|i| {
                    (0..d)
                        .map(|r| {
                            if r == i {
                                Some(Arc::new(requests[q][r].clone()))
                            } else {
                                None
                            }
                        })
                        .collect()
                })
                .collect()
        })
        .collect();
    let plans: Vec<_> = (0..d).map(|i| all_gather_steps(i, d)).collect();
    for s in 0..d {
        // Wire: every device posts its step-s tile for every request —
        // interleaved traffic sharing each link's slots (lockstep models
        // simultaneous full-duplex sends).
        for q in 0..nq {
            for i in 0..d {
                if let Some(t) = plans[i][s].send_tile {
                    let payload = tiles[q][i][t].clone().ok_or_else(|| {
                        GalaxyError::Fabric(format!("dev {i} step {s}: tile {t} not yet held"))
                    })?;
                    links[i].0.post_send(codec.encode(&payload)?)?;
                }
            }
        }
        // (compute_tile is where the engine would run the entry GEMM,
        // overlapping the in-flight transfers posted above.)
        for q in 0..nq {
            for i in 0..d {
                if let Some(r) = plans[i][s].recv_tile {
                    if !links[i].1.try_recv()? {
                        return Err(GalaxyError::Fabric(format!(
                            "dev {i} step {s}: tile {r} did not arrive — schedule broken"
                        )));
                    }
                    tiles[q][i][r] = Some(links[i].1.complete_recv()?.decode()?);
                }
                let ct = plans[i][s].compute_tile;
                if tiles[q][i][ct].is_none() {
                    return Err(GalaxyError::Fabric(format!(
                        "dev {i} step {s}: compute tile {ct} missing — schedule broken"
                    )));
                }
            }
        }
    }
    tiles
        .into_iter()
        .map(|per_dev| {
            per_dev
                .into_iter()
                .map(|mut held| {
                    let parts = (0..d)
                        .map(|r| {
                            held[r].take().map(take_tile).ok_or_else(|| {
                                GalaxyError::Fabric(format!("AG: tile {r} missing after walk"))
                            })
                        })
                        .collect::<Result<Vec<Tensor2>>>()?;
                    Tensor2::concat_rows(&parts)
                })
                .collect()
        })
        .collect()
}

/// Ring-ReduceScatter executed in lockstep, following
/// [`reduce_scatter_steps`]. `partials[i]` is device i's full `[seq, h]`
/// partial; `seq_parts` the row-tile sizes. Returns, per device, its fully
/// reduced tile (device i gets tile i).
pub fn ring_reduce_scatter(partials: &[Tensor2], seq_parts: &[usize]) -> Result<Vec<Tensor2>> {
    ring_reduce_scatter_wire(partials, seq_parts, WireFormat::F32)
}

/// [`ring_reduce_scatter`] with an explicit wire format. Unlike AllGather
/// (where re-encoding a decoded tile is idempotent), ReduceScatter
/// re-quantizes the *running sum* on every hop, so the lossy-format error
/// bound scales with `d - 1`.
pub fn ring_reduce_scatter_wire(
    partials: &[Tensor2],
    seq_parts: &[usize],
    format: WireFormat,
) -> Result<Vec<Tensor2>> {
    let req = (partials.to_vec(), seq_parts.to_vec());
    let mut per_req = ring_reduce_scatter_multi_wire(std::slice::from_ref(&req), format)?;
    per_req
        .pop()
        .ok_or_else(|| GalaxyError::Fabric("ring_reduce_scatter: one request in, none out".into()))
}

/// Lockstep Ring-ReduceScatter for one or more interleaved requests over
/// one shared set of double-buffered in-process links (see
/// [`ring_all_gather_multi`]). `requests[q]` is `(partials, seq_parts)`
/// — partitions may differ per request. Returns, per request, each
/// device's fully reduced tile.
pub fn ring_reduce_scatter_multi(
    requests: &[(Vec<Tensor2>, Vec<usize>)],
) -> Result<Vec<Vec<Tensor2>>> {
    ring_reduce_scatter_multi_wire(requests, WireFormat::F32)
}

/// [`ring_reduce_scatter_multi`] with an explicit wire format (see
/// [`ring_reduce_scatter_wire`]).
pub fn ring_reduce_scatter_multi_wire(
    requests: &[(Vec<Tensor2>, Vec<usize>)],
    format: WireFormat,
) -> Result<Vec<Vec<Tensor2>>> {
    let d = requests.first().map(|(p, _)| p.len()).unwrap_or(0);
    if d == 0 {
        return Err(GalaxyError::Shape("ring_reduce_scatter: empty".into()));
    }
    for (partials, seq_parts) in requests {
        if partials.len() != d || seq_parts.len() != d {
            return Err(GalaxyError::Shape(format!(
                "ring_reduce_scatter: {} devices vs {} parts",
                partials.len(),
                seq_parts.len()
            )));
        }
    }
    let nq = requests.len();
    let mut links = mem_ring(d, LINK_SLOTS);
    let codec = TileCodec::new(format);
    let offsets: Vec<Vec<usize>> = requests
        .iter()
        .map(|(_, parts)| (0..d).map(|r| parts[..r].iter().sum()).collect())
        .collect();
    let tile_of = |q: usize, i: usize, r: usize| -> Result<Tensor2> {
        requests[q].0[i].slice_rows(offsets[q][r], requests[q].1[r])
    };
    let plans: Vec<_> = (0..d).map(|i| reduce_scatter_steps(i, d)).collect();
    // acc[q][i] = the partial-sum tile device i accumulated last step.
    let mut acc: Vec<Vec<Option<Arc<Tensor2>>>> = vec![vec![None; d]; nq];
    for s in 0..d {
        // Wire: forward last step's accumulations first (they ride the
        // ring while this step's exit GEMMs run).
        for q in 0..nq {
            for i in 0..d {
                if plans[i][s].send_tile.is_some() {
                    let t = acc[q][i].take().ok_or_else(|| {
                        GalaxyError::Fabric(format!("dev {i} had nothing to send at step {s}"))
                    })?;
                    links[i].0.post_send(codec.encode(&t)?)?;
                }
            }
        }
        // Compute each device's GEMM-output tile (here: slices its own
        // partial — the engine plugs real GEMMs in), then reduce-add the
        // partial arriving from the predecessor.
        for q in 0..nq {
            for i in 0..d {
                let mut mine = tile_of(q, i, plans[i][s].compute_tile)?;
                if plans[i][s].recv_tile.is_some() {
                    mine.add_assign(&links[i].1.complete_recv()?.decode()?)?;
                }
                acc[q][i] = Some(Arc::new(mine));
            }
        }
    }
    acc.into_iter()
        .map(|per_dev| {
            per_dev
                .into_iter()
                .enumerate()
                .map(|(i, a)| {
                    a.map(take_tile).ok_or_else(|| {
                        GalaxyError::Fabric(format!("RS: device {i} never accumulated"))
                    })
                })
                .collect::<Result<Vec<Tensor2>>>()
        })
        .collect()
}

/// Shared validation for the micro-grain lockstep walks: the grain must
/// be a positive multiple of the ring size, and every tile must have at
/// least `per = grain/d` rows to split.
fn check_micro_grain(d: usize, grain: usize, min_rows: usize) -> Result<usize> {
    if grain < d || grain % d != 0 {
        return Err(GalaxyError::Config(format!(
            "overlap grain {grain} is not a multiple of the ring size {d}"
        )));
    }
    let per = grain / d;
    if min_rows < per {
        return Err(GalaxyError::Config(format!(
            "overlap grain {grain} needs {per} micro-tiles per SP row but the \
             smallest tile has only {min_rows} rows"
        )));
    }
    Ok(per)
}

/// Row-slice micro `micro` of `per` out of a tile, using the same split
/// as the schedules ([`micro_rows`]).
fn micro_slice(t: &Tensor2, per: usize, micro: usize) -> Result<Tensor2> {
    let rows = micro_rows(t.rows(), per);
    let off: usize = rows[..micro].iter().sum();
    t.slice_rows(off, rows[micro])
}

/// Lockstep micro-grain Ring-AllGather for one or more interleaved
/// requests: the planner-grain refinement of
/// [`ring_all_gather_multi_wire`]. Each device's tile splits into
/// `grain/d` row-sliced micro-tiles and every lockstep sub-step moves
/// one micro-tile per request over the shared double-buffered links —
/// so two requests' **micro**-tiles share each link's [`LINK_SLOTS`]
/// slots exactly like their coarse tiles do, and a third request still
/// backpressures. At f32 the result is bit-identical to the coarse walk
/// for every grain (pure slicing and reassembly).
pub fn ring_all_gather_micro_wire(
    requests: &[Vec<Tensor2>],
    format: WireFormat,
    grain: usize,
) -> Result<Vec<Vec<Tensor2>>> {
    let d = requests.first().map(|r| r.len()).unwrap_or(0);
    if d == 0 {
        return Err(GalaxyError::Shape("ring_all_gather: empty".into()));
    }
    if requests.iter().any(|r| r.len() != d) {
        return Err(GalaxyError::Shape("ring_all_gather: uneven device counts".into()));
    }
    let min_rows = requests.iter().flatten().map(Tensor2::rows).min().unwrap_or(0);
    let per = check_micro_grain(d, grain, min_rows)?;
    let nq = requests.len();
    let mut links = mem_ring(d, LINK_SLOTS);
    let codec = TileCodec::new(format);
    let mut tiles: Vec<Vec<Vec<Option<Arc<Tensor2>>>>> = (0..nq)
        .map(|q| {
            (0..d)
                .map(|i| {
                    (0..d)
                        .map(|r| {
                            if r == i {
                                Some(Arc::new(requests[q][r].clone()))
                            } else {
                                None
                            }
                        })
                        .collect()
                })
                .collect()
        })
        .collect();
    // inbox[q][i]: decoded micro-slices of the tile device i is currently
    // receiving for request q (arrival order == row order).
    let mut inbox: Vec<Vec<Vec<Arc<Tensor2>>>> = vec![vec![Vec::new(); d]; nq];
    let plans: Vec<_> = (0..d).map(|i| all_gather_micro_steps(i, d, grain)).collect();
    for u in 0..d * per {
        // Wire: every device posts its sub-step micro for every request
        // before any is consumed — interleaved micro-traffic sharing the
        // slots, exactly the coarse contract.
        for q in 0..nq {
            for (i, link) in links.iter_mut().enumerate() {
                if let Some(send) = plans[i][u].send {
                    let held = tiles[q][i][send.tile].clone().ok_or_else(|| {
                        GalaxyError::Fabric(format!(
                            "dev {i} sub-step {u}: tile {} not yet held",
                            send.tile
                        ))
                    })?;
                    let payload = Arc::new(micro_slice(&held, per, send.micro)?);
                    link.0.post_send(codec.encode(&payload)?)?;
                }
            }
        }
        for q in 0..nq {
            for (i, link) in links.iter_mut().enumerate() {
                if let Some(recv) = plans[i][u].recv {
                    if !link.1.try_recv()? {
                        return Err(GalaxyError::Fabric(format!(
                            "dev {i} sub-step {u}: micro of tile {} did not arrive — \
                             schedule broken",
                            recv.tile
                        )));
                    }
                    inbox[q][i].push(link.1.complete_recv()?.decode()?);
                    if recv.micro + 1 == per {
                        let parts: Vec<Tensor2> =
                            inbox[q][i].drain(..).map(take_tile).collect();
                        tiles[q][i][recv.tile] = Some(Arc::new(Tensor2::concat_rows(&parts)?));
                    }
                }
                let c = plans[i][u].compute;
                if c.micro == 0 && tiles[q][i][c.tile].is_none() {
                    return Err(GalaxyError::Fabric(format!(
                        "dev {i} sub-step {u}: compute tile {} missing — schedule broken",
                        c.tile
                    )));
                }
            }
        }
    }
    tiles
        .into_iter()
        .map(|per_dev| {
            per_dev
                .into_iter()
                .map(|mut held| {
                    let parts = (0..d)
                        .map(|r| {
                            held[r].take().map(take_tile).ok_or_else(|| {
                                GalaxyError::Fabric(format!("AG: tile {r} missing after walk"))
                            })
                        })
                        .collect::<Result<Vec<Tensor2>>>()?;
                    Tensor2::concat_rows(&parts)
                })
                .collect()
        })
        .collect()
}

/// Lockstep micro-grain Ring-ReduceScatter for one or more interleaved
/// requests: the planner-grain refinement of
/// [`ring_reduce_scatter_multi_wire`]. The previous coarse step's
/// accumulation is forwarded one micro-slice per sub-step; arriving
/// micro partials reduce-add into their row range of the running tile.
/// At f32 each element sees the same additions in the same hop order as
/// the coarse walk, so the reduced tiles are bit-identical.
pub fn ring_reduce_scatter_micro_wire(
    requests: &[(Vec<Tensor2>, Vec<usize>)],
    format: WireFormat,
    grain: usize,
) -> Result<Vec<Vec<Tensor2>>> {
    let d = requests.first().map(|(p, _)| p.len()).unwrap_or(0);
    if d == 0 {
        return Err(GalaxyError::Shape("ring_reduce_scatter: empty".into()));
    }
    for (partials, seq_parts) in requests {
        if partials.len() != d || seq_parts.len() != d {
            return Err(GalaxyError::Shape(format!(
                "ring_reduce_scatter: {} devices vs {} parts",
                partials.len(),
                seq_parts.len()
            )));
        }
    }
    let min_rows =
        requests.iter().flat_map(|(_, parts)| parts.iter().copied()).min().unwrap_or(0);
    let per = check_micro_grain(d, grain, min_rows)?;
    let nq = requests.len();
    let mut links = mem_ring(d, LINK_SLOTS);
    let codec = TileCodec::new(format);
    let offsets: Vec<Vec<usize>> = requests
        .iter()
        .map(|(_, parts)| (0..d).map(|r| parts[..r].iter().sum()).collect())
        .collect();
    let tile_of = |q: usize, i: usize, r: usize| -> Result<Tensor2> {
        requests[q].0[i].slice_rows(offsets[q][r], requests[q].1[r])
    };
    let plans: Vec<_> = (0..d).map(|i| reduce_scatter_micro_steps(i, d, grain)).collect();
    // acc[q][i] = the fully accumulated tile of the previous coarse step
    // (being forwarded micro by micro); cur[q][i] = the tile this coarse
    // step is reducing into.
    let mut acc: Vec<Vec<Option<Arc<Tensor2>>>> = vec![vec![None; d]; nq];
    let mut cur: Vec<Vec<Option<Tensor2>>> = vec![vec![None; d]; nq];
    for u in 0..d * per {
        for q in 0..nq {
            for (i, link) in links.iter_mut().enumerate() {
                if let Some(send) = plans[i][u].send {
                    let t = acc[q][i].clone().ok_or_else(|| {
                        GalaxyError::Fabric(format!(
                            "dev {i} had nothing to send at sub-step {u}"
                        ))
                    })?;
                    let payload = Arc::new(micro_slice(&t, per, send.micro)?);
                    link.0.post_send(codec.encode(&payload)?)?;
                    if send.micro + 1 == per {
                        acc[q][i] = None; // fully forwarded
                    }
                }
            }
        }
        for q in 0..nq {
            for (i, link) in links.iter_mut().enumerate() {
                let step = plans[i][u];
                if step.compute.micro == 0 {
                    cur[q][i] = Some(tile_of(q, i, step.compute.tile)?);
                }
                if let Some(recv) = step.recv {
                    let got = link.1.complete_recv()?.decode()?;
                    let o = cur[q][i].as_mut().ok_or_else(|| {
                        GalaxyError::Fabric(format!(
                            "dev {i} sub-step {u}: micro partial arrived before its tile"
                        ))
                    })?;
                    let rows = micro_rows(o.rows(), per);
                    let off: usize = rows[..recv.micro].iter().sum();
                    o.add_assign_rows(off, &got)?;
                }
                if step.compute.micro + 1 == per {
                    let done = cur[q][i].take().ok_or_else(|| {
                        GalaxyError::Fabric(format!(
                            "dev {i} sub-step {u}: coarse step ended with no tile"
                        ))
                    })?;
                    acc[q][i] = Some(Arc::new(done));
                }
            }
        }
    }
    acc.into_iter()
        .map(|per_dev| {
            per_dev
                .into_iter()
                .enumerate()
                .map(|(i, a)| {
                    a.map(take_tile).ok_or_else(|| {
                        GalaxyError::Fabric(format!("RS: device {i} never accumulated"))
                    })
                })
                .collect::<Result<Vec<Tensor2>>>()
        })
        .collect()
}

/// Ring-AllReduce = Ring-ReduceScatter + Ring-AllGather (the Megatron-LM
/// baseline synchronization; paper §III-B.5 merit 2).
pub fn ring_all_reduce(partials: &[Tensor2], seq_parts: &[usize]) -> Result<Vec<Tensor2>> {
    let scattered = ring_reduce_scatter(partials, seq_parts)?;
    ring_all_gather(&scattered)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testkit::{forall, Pcg64};

    fn rand_tensor(rng: &mut Pcg64, rows: usize, cols: usize) -> Tensor2 {
        Tensor2::from_vec(rows, cols, (0..rows * cols).map(|_| rng.normal()).collect()).unwrap()
    }

    #[test]
    fn ring_ag_matches_reference_equal_parts() {
        let mut rng = Pcg64::new(1);
        for d in 1..=5 {
            let shards: Vec<Tensor2> = (0..d).map(|_| rand_tensor(&mut rng, 4, 6)).collect();
            let want = reference::all_gather(&shards).unwrap();
            for got in ring_all_gather(&shards).unwrap() {
                assert_eq!(got, want, "d={d}");
            }
        }
    }

    #[test]
    fn ring_ag_unequal_parts() {
        let mut rng = Pcg64::new(2);
        let shards = vec![
            rand_tensor(&mut rng, 5, 3),
            rand_tensor(&mut rng, 2, 3),
            rand_tensor(&mut rng, 7, 3),
        ];
        let want = reference::all_gather(&shards).unwrap();
        for got in ring_all_gather(&shards).unwrap() {
            assert_eq!(got, want);
        }
    }

    #[test]
    fn ring_rs_matches_reference() {
        let mut rng = Pcg64::new(3);
        for d in 1..=5 {
            let parts: Vec<usize> = (0..d).map(|r| 2 + r).collect();
            let seq: usize = parts.iter().sum();
            let partials: Vec<Tensor2> = (0..d).map(|_| rand_tensor(&mut rng, seq, 4)).collect();
            let want = reference::reduce_scatter(&partials, &parts).unwrap();
            let got = ring_reduce_scatter(&partials, &parts).unwrap();
            for (g, w) in got.iter().zip(want.iter()) {
                assert!(g.allclose(w, 1e-5, 1e-5), "d={d}");
            }
        }
    }

    #[test]
    fn ring_all_reduce_matches_reference() {
        let mut rng = Pcg64::new(4);
        let d = 3;
        let parts = vec![3usize, 3, 2];
        let partials: Vec<Tensor2> = (0..d).map(|_| rand_tensor(&mut rng, 8, 5)).collect();
        let want = reference::all_reduce(&partials).unwrap();
        for got in ring_all_reduce(&partials, &parts).unwrap() {
            assert!(got.allclose(&want, 1e-5, 1e-5));
        }
    }

    #[test]
    fn allreduce_volume_identity() {
        // Paper §III-B.5: Ring-AllReduce volume == Ring-RS + Ring-AG.
        // AllReduce classic volume per device: 2*(D-1)/D * N bytes; our RS
        // and AG helpers each move (D-1)*chunk where chunk = N/D.
        let n_bytes = 1_000_000u64;
        for d in 2..=6 {
            let chunk = n_bytes / d as u64;
            let rs_ag = rs_bytes_per_device(chunk, d) + ag_bytes_per_device(chunk, d);
            let allreduce = 2 * (d as u64 - 1) * chunk;
            assert_eq!(rs_ag, allreduce, "d={d}");
        }
    }

    #[test]
    fn empty_inputs_error() {
        assert!(ring_all_gather(&[]).is_err());
        assert!(ring_reduce_scatter(&[], &[]).is_err());
        assert!(reference::all_reduce(&[]).is_err());
        assert!(ring_all_gather_multi(&[]).is_err());
        assert!(ring_reduce_scatter_multi(&[]).is_err());
    }

    #[test]
    fn transport_interleaved_requests_share_link_slots() {
        // Two requests' tiles ride the same double-buffered links and
        // both still match the reference — the collective-level picture
        // of the cluster's layer-granular request interleaving.
        let mut rng = Pcg64::new(21);
        for d in 1..=5 {
            let reqs: Vec<Vec<Tensor2>> = (0..2)
                .map(|_| (0..d).map(|_| rand_tensor(&mut rng, 3, 4)).collect())
                .collect();
            let got = ring_all_gather_multi(&reqs).unwrap();
            for (q, req) in reqs.iter().enumerate() {
                let want = reference::all_gather(req).unwrap();
                for per_dev in &got[q] {
                    assert_eq!(*per_dev, want, "d={d} q={q}");
                }
            }
        }
    }

    #[test]
    fn transport_third_interleaved_request_backpressures() {
        // The links double-buffer: two interleaved requests fit the
        // slots exactly, a third must surface as backpressure (in the
        // single-threaded lockstep a would-block is a deadlock).
        let mut rng = Pcg64::new(22);
        let d = 3;
        let reqs: Vec<Vec<Tensor2>> = (0..3)
            .map(|_| (0..d).map(|_| rand_tensor(&mut rng, 2, 2)).collect())
            .collect();
        let err = ring_all_gather_multi(&reqs).unwrap_err();
        assert!(err.to_string().contains("backpressure"), "{err}");
    }

    #[test]
    fn transport_interleaved_rs_with_uneven_partitions() {
        let mut rng = Pcg64::new(23);
        for d in 2..=5 {
            let reqs: Vec<(Vec<Tensor2>, Vec<usize>)> = (0..2)
                .map(|_| {
                    let parts: Vec<usize> =
                        (0..d).map(|_| rng.range(1, 4) as usize).collect();
                    let seq: usize = parts.iter().sum();
                    let partials: Vec<Tensor2> =
                        (0..d).map(|_| rand_tensor(&mut rng, seq, 3)).collect();
                    (partials, parts)
                })
                .collect();
            let got = ring_reduce_scatter_multi(&reqs).unwrap();
            for (q, (partials, parts)) in reqs.iter().enumerate() {
                let want = reference::reduce_scatter(partials, parts).unwrap();
                for (g, w) in got[q].iter().zip(want.iter()) {
                    assert!(g.allclose(w, 1e-5, 1e-5), "d={d} q={q}");
                }
            }
        }
    }

    #[test]
    fn micro_grain_collectives_reproduce_plain_bit_exact() {
        // The tentpole equivalence property: for every ring size d ≤ 8
        // and grain T ∈ {d, 2d, 4d} over uneven SP partitions, the
        // micro-grain walks at f32 reproduce the plain (coarse) ring
        // walks bit-exactly — AG is pure slicing and reassembly, RS
        // applies the same additions in the same hop order.
        let mut rng = Pcg64::new(41);
        for d in 1..=8usize {
            for mult in [1usize, 2, 4] {
                let grain = mult * d;
                // Uneven partition; ≥ 4 rows so every tile splits 4 ways.
                let parts: Vec<usize> = (0..d).map(|_| rng.range(4, 9) as usize).collect();
                let shards: Vec<Tensor2> =
                    parts.iter().map(|&r| rand_tensor(&mut rng, r, 3)).collect();
                let want_ag = reference::all_gather(&shards).unwrap();
                let got_ag = ring_all_gather_micro_wire(
                    std::slice::from_ref(&shards),
                    WireFormat::F32,
                    grain,
                )
                .unwrap();
                for per_dev in &got_ag[0] {
                    assert_eq!(*per_dev, want_ag, "AG d={d} T={grain}");
                }
                let seq: usize = parts.iter().sum();
                let partials: Vec<Tensor2> =
                    (0..d).map(|_| rand_tensor(&mut rng, seq, 3)).collect();
                // Coarse lockstep is the bit-exactness oracle (the naive
                // reference sums in a different order).
                let want_rs = ring_reduce_scatter(&partials, &parts).unwrap();
                let req = (partials, parts);
                let got_rs = ring_reduce_scatter_micro_wire(
                    std::slice::from_ref(&req),
                    WireFormat::F32,
                    grain,
                )
                .unwrap();
                assert_eq!(got_rs[0], want_rs, "RS d={d} T={grain}");
            }
        }
    }

    #[test]
    fn micro_interleaved_requests_share_slots_without_ordering_loss() {
        // Two requests' micro-tiles ride the same double-buffered links:
        // both must come out exactly right (no ordering loss between the
        // interleaved micro streams), and a third concurrent request
        // still backpressures at LINK_SLOTS regardless of the grain.
        let mut rng = Pcg64::new(42);
        let d = 3;
        let grain = 2 * d;
        let reqs: Vec<Vec<Tensor2>> = (0..2)
            .map(|_| (0..d).map(|_| rand_tensor(&mut rng, 4, 3)).collect())
            .collect();
        let got = ring_all_gather_micro_wire(&reqs, WireFormat::F32, grain).unwrap();
        for (q, req) in reqs.iter().enumerate() {
            let want = reference::all_gather(req).unwrap();
            for per_dev in &got[q] {
                assert_eq!(*per_dev, want, "q={q}");
            }
        }
        let reqs3: Vec<Vec<Tensor2>> = (0..3)
            .map(|_| (0..d).map(|_| rand_tensor(&mut rng, 4, 3)).collect())
            .collect();
        let err = ring_all_gather_micro_wire(&reqs3, WireFormat::F32, grain).unwrap_err();
        assert!(err.to_string().contains("backpressure"), "{err}");
    }

    #[test]
    fn micro_grain_rejects_oversplit_tiles() {
        // A grain demanding more micro-tiles than a tile has rows must be
        // a Config error at the walk boundary, not a panic mid-ring.
        let mut rng = Pcg64::new(43);
        let shards: Vec<Tensor2> = (0..2).map(|_| rand_tensor(&mut rng, 2, 3)).collect();
        let err = ring_all_gather_micro_wire(
            std::slice::from_ref(&shards),
            WireFormat::F32,
            8, // per = 4 > 2 rows
        )
        .unwrap_err();
        assert!(err.to_string().contains("micro-tiles"), "{err}");
        let err = ring_all_gather_micro_wire(std::slice::from_ref(&shards), WireFormat::F32, 3)
            .unwrap_err();
        assert!(err.to_string().contains("multiple of the ring size"), "{err}");
    }

    #[test]
    fn prop_ring_ag_equals_reference() {
        forall(
            "ring_ag==naive_ag",
            7,
            60,
            |rng| {
                let d = rng.range(1, 6) as usize;
                let cols = rng.range(1, 8) as usize;
                let shards: Vec<Tensor2> = (0..d)
                    .map(|_| {
                        let rows = rng.range(1, 6) as usize;
                        rand_tensor(rng, rows, cols)
                    })
                    .collect();
                shards
            },
            |shards| {
                let want = reference::all_gather(shards).map_err(|e| e.to_string())?;
                let got = ring_all_gather(shards).map_err(|e| e.to_string())?;
                for g in got {
                    if g != want {
                        return Err("mismatch".into());
                    }
                }
                Ok(())
            },
        );
    }

    #[test]
    fn quantized_ring_parity_across_ladder() {
        // Artifact-free mock-cluster ring parity: AG and RS outputs land
        // within each wire format's stated tolerance of the reference —
        // exact for F32, bounded for F16/I8 — across d=1..4 and every
        // ladder rung (the rung is the total sequence length split
        // near-evenly across devices).
        let mut rng = Pcg64::new(31);
        let max_abs =
            |t: &Tensor2| t.data().iter().fold(0.0f32, |m, v| m.max(v.abs()));
        for &rung in crate::engine::DEFAULT_SEQ_BUCKETS.iter() {
            for d in 1..=4usize {
                let base = rung / d;
                let parts: Vec<usize> =
                    (0..d).map(|r| base + usize::from(r < rung % d)).collect();
                let shards: Vec<Tensor2> =
                    parts.iter().map(|&rows| rand_tensor(&mut rng, rows, 3)).collect();
                let want_ag = reference::all_gather(&shards).unwrap();
                let partials: Vec<Tensor2> =
                    (0..d).map(|_| rand_tensor(&mut rng, rung, 3)).collect();
                let want_rs = reference::reduce_scatter(&partials, &parts).unwrap();
                // AG hops re-encode idempotently, so every device carries
                // one encode's error; RS re-quantizes the running sum on
                // each of its d-1 reduce hops, so its bound scales with d.
                for format in WireFormat::all() {
                    // I8 scales are per-channel (row-wise max-abs), so the
                    // true per-row bound is max|row|/254 ≤ this tile-max
                    // bound — the tile-max form stays a valid ceiling.
                    let per_encode = |m: f32| match format {
                        WireFormat::F32 => 0.0f32,
                        WireFormat::F16 => m * 2.0f32.powi(-11) + 2.0f32.powi(-24),
                        WireFormat::I8 => m / 254.0 + 1e-6,
                    };
                    let ag_tol = if d > 1 { per_encode(max_abs(&want_ag)) } else { 0.0 };
                    let sum_mag: f32 = partials.iter().map(|p| max_abs(p)).sum();
                    let rs_tol = (d as f32 - 1.0) * per_encode(sum_mag);

                    let got_ag = ring_all_gather_wire(&shards, format).unwrap();
                    for g in &got_ag {
                        if format == WireFormat::F32 || d == 1 {
                            assert_eq!(*g, want_ag, "{format} d={d} rung={rung}");
                        } else {
                            let diff = g.max_abs_diff(&want_ag).unwrap();
                            assert!(
                                diff <= ag_tol,
                                "AG {format} d={d} rung={rung}: {diff} > {ag_tol}"
                            );
                        }
                    }
                    let got_rs = ring_reduce_scatter_wire(&partials, &parts, format).unwrap();
                    for (g, w) in got_rs.iter().zip(want_rs.iter()) {
                        if format == WireFormat::F32 || d == 1 {
                            assert!(
                                g.allclose(w, 1e-5, 1e-5),
                                "RS {format} d={d} rung={rung}"
                            );
                        } else {
                            let diff = g.max_abs_diff(w).unwrap();
                            assert!(
                                diff <= rs_tol,
                                "RS {format} d={d} rung={rung}: {diff} > {rs_tol}"
                            );
                        }
                    }
                }
            }
        }
    }

    #[test]
    fn prop_ring_rs_equals_reference() {
        forall(
            "ring_rs==naive_rs",
            8,
            60,
            |rng| {
                let d = rng.range(1, 6) as usize;
                let cols = rng.range(1, 8) as usize;
                let parts: Vec<usize> = (0..d).map(|_| rng.range(1, 5) as usize).collect();
                let seq: usize = parts.iter().sum();
                let partials: Vec<Tensor2> =
                    (0..d).map(|_| rand_tensor(rng, seq, cols)).collect();
                (partials, parts)
            },
            |(partials, parts)| {
                let want = reference::reduce_scatter(partials, parts).map_err(|e| e.to_string())?;
                let got = ring_reduce_scatter(partials, parts).map_err(|e| e.to_string())?;
                for (g, w) in got.iter().zip(want.iter()) {
                    if !g.allclose(w, 1e-4, 1e-4) {
                        return Err(format!("diff {}", g.max_abs_diff(w).unwrap()));
                    }
                }
                Ok(())
            },
        );
    }
}
