//! Regenerates **paper Table V**: Galaxy vs baselines on the mobile-GPU
//! environment (2 × Jetson Nano Maxwell GPU locked at 460 MHz, 500 Mbps).
//! Paper: 1.36x–1.67x over M-LM, 1.12x–1.35x over SP.
//!
//! Run: `cargo bench --bench table5_gpu`

#[path = "bench_util.rs"]
#[allow(dead_code)]
mod bench_util;

use bench_util::{baseline_latency, galaxy_latency, speedup_cell};
use galaxy::baselines::BaselineKind;
use galaxy::metrics::{fmt_secs, Table};
use galaxy::model::{ModelConfig, ModelKind};
use galaxy::sim::EdgeEnv;

const MBPS: f64 = 500.0;
const SEQ: usize = 284;

fn main() {
    let env = EdgeEnv::preset_gpu();
    let mut t = Table::new(
        "Table V — mobile GPU environment (2x Nano-GPU @460MHz, 500 Mbps)",
        &["model", "Galaxy", "vs M-LM", "vs SP", "paper M-LM", "paper SP"],
    );
    let paper = [("1.36x", "1.12x"), ("1.57x", "1.24x"), ("1.67x", "1.35x"), ("1.58x", "1.26x"), ("1.47x", "1.19x")];
    for (kind, (pm, ps)) in ModelKind::ALL_PAPER.iter().zip(paper.iter()) {
        let model = ModelConfig::by_kind(*kind);
        let g = galaxy_latency(&model, &env, MBPS, SEQ);
        let m = baseline_latency(BaselineKind::MegatronLm, &model, &env, MBPS, SEQ);
        let s = baseline_latency(BaselineKind::SeqPar, &model, &env, MBPS, SEQ);
        t.row(&[
            model.kind.name().into(),
            g.map(fmt_secs).unwrap_or_else(|| "OOM".into()),
            speedup_cell(g, m),
            speedup_cell(g, s),
            pm.to_string(),
            ps.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("note: GPU compute is ~4x the Nano CPU, so communication dominates more");
    println!("and both the planner and the tile-based overlap matter more (paper §IV-E).");
}
