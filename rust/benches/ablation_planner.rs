//! Ablation: heterogeneity-aware planning (Algorithm 1) vs a naive equal
//! split, and the memory-aware rebalancing step vs capacity-only
//! planning — quantifying each planner ingredient's contribution on the
//! heterogeneous envs of Fig 9.
//!
//! Run: `cargo bench --bench ablation_planner`

#[path = "bench_util.rs"]
#[allow(dead_code)]
mod bench_util;

use bench_util::plan_outcome;
use galaxy::metrics::Table;
use galaxy::model::{ModelConfig, ModelKind};
use galaxy::parallel::OverlapMode;
use galaxy::planner::{equal_seq_partition, quantize_shares, Partition, Plan, Planner};
use galaxy::profiler::Profiler;
use galaxy::sim::EdgeEnv;

const MBPS: f64 = 125.0;
const SEQ: usize = 284;

fn latency_for_partition(model: &ModelConfig, env: &EdgeEnv, heads: Vec<usize>, units: Vec<usize>) -> f64 {
    let plan = Plan {
        partition: Partition {
            heads,
            mlp_units: units,
            seq: equal_seq_partition(SEQ, env.len()),
        },
        pred_mha_s: 0.0,
        pred_mlp_s: 0.0,
        pred_conn_s: 0.0,
        mem_mb: vec![0.0; env.len()],
    };
    plan_outcome(model, env, plan, MBPS, SEQ, OverlapMode::Tiled).total_s()
}

fn main() {
    let mut t = Table::new(
        "Ablation — planner ingredients (125 Mbps, seq 284)",
        &["env", "model", "equal split", "capacity-aware", "gain", "planned heads"],
    );
    for env in [EdgeEnv::preset_d(), EdgeEnv::preset_e(), EdgeEnv::preset_f()] {
        for kind in [ModelKind::BertLarge, ModelKind::Gpt2Large] {
            let model = ModelConfig::by_kind(kind);
            let d = env.len();
            let naive_units = quantize_shares(&vec![1.0 / d as f64; d], model.heads);
            let naive = latency_for_partition(&model, &env, naive_units.clone(), naive_units);
            let profile = Profiler::analytic(&model, &env, SEQ).profile();
            let plan = match Planner::new(&model, &env, &profile).plan() {
                Ok(p) => p,
                Err(_) => continue,
            };
            let heads_str = format!("{:?}", plan.partition.heads);
            let aware = plan_outcome(&model, &env, plan, MBPS, SEQ, OverlapMode::Tiled).total_s();
            t.row(&[
                env.name.clone(),
                model.kind.name().into(),
                format!("{:.0} ms", naive * 1e3),
                format!("{:.0} ms", aware * 1e3),
                format!("{:.1}%", 100.0 * (1.0 - aware / naive)),
                heads_str,
            ]);
        }
    }
    println!("{}", t.render());
    println!("equal split straggles on the slowest device; Algorithm 1 balances");
    println!("completion times (paper §III-C), which is where Fig 9's 1.3–2.5x lives.");
}
