//! Regenerates **paper Table IV**: Galaxy's speedup over Megatron-LM (TP)
//! and Sequence Parallelism across homogeneous edge environments A/B/C at
//! 125 Mbps, sequence length 284 (QNLI subset average).
//!
//! Expected shape (paper): 1.26x–1.46x over M-LM, ~1.08–1.11x over SP
//! where SP fits; SP OOMs from GPT2-L up; M-LM OOMs OPT-XL on A and B.
//!
//! Run: `cargo bench --bench table4_homogeneous`

#[path = "bench_util.rs"]
#[allow(dead_code)]
mod bench_util;

use bench_util::{baseline_latency, galaxy_latency, speedup_cell};
use galaxy::baselines::BaselineKind;
use galaxy::metrics::{fmt_secs, Table};
use galaxy::model::{ModelConfig, ModelKind};
use galaxy::sim::EdgeEnv;

const MBPS: f64 = 125.0;
const SEQ: usize = 284;

fn main() {
    let mut t = Table::new(
        "Table IV — speedup over baselines (homogeneous envs, 125 Mbps, seq 284)",
        &["model", "layers/heads/hidden", "env", "galaxy", "vs M-LM", "vs SP", "paper M-LM", "paper SP"],
    );
    // (model, env, paper M-LM cell, paper SP cell)
    let env_a = EdgeEnv::preset_a();
    let env_b = EdgeEnv::preset_b();
    let env_c = EdgeEnv::preset_c();
    let cases: &[(ModelKind, &EdgeEnv, &str, &str)] = &[
        (ModelKind::DistilBert, &env_a, "1.37x", "1.08x"),
        (ModelKind::BertLarge, &env_a, "1.36x", "1.09x"),
        (ModelKind::BertLarge, &env_b, "1.38x", "1.11x"),
        (ModelKind::Gpt2Large, &env_a, "1.31x", "OOM"),
        (ModelKind::Gpt2Large, &env_b, "1.46x", "OOM"),
        (ModelKind::OptLarge, &env_a, "1.26x", "OOM"),
        (ModelKind::OptLarge, &env_b, "1.40x", "OOM"),
        (ModelKind::OptLarge, &env_c, "1.43x", "OOM"),
        (ModelKind::OptXl, &env_a, "OOM", "OOM"),
        (ModelKind::OptXl, &env_b, "OOM", "OOM"),
        (ModelKind::OptXl, &env_c, "1.28x", "OOM"),
    ];
    for (kind, env, paper_mlm, paper_sp) in cases {
        let model = ModelConfig::by_kind(*kind);
        let g = galaxy_latency(&model, env, MBPS, SEQ);
        let mlm = baseline_latency(BaselineKind::MegatronLm, &model, env, MBPS, SEQ);
        let sp = baseline_latency(BaselineKind::SeqPar, &model, env, MBPS, SEQ);
        t.row(&[
            model.kind.name().into(),
            format!("{}/{}/{}", model.layers, model.heads, model.hidden),
            env.name.clone(),
            g.map(fmt_secs).unwrap_or_else(|| "OOM".into()),
            speedup_cell(g, mlm),
            speedup_cell(g, sp),
            paper_mlm.to_string(),
            paper_sp.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("OOM  = baseline cannot host the model (matches paper cells)");
    println!("OOM* = cluster aggregate memory cannot host the model at all");
}
