//! Regenerates **paper Table I**: single-device inference latency and
//! memory footprint of five Transformer models on Nano-M vs A100 at
//! sequence length 30 — the motivation measurement (121x gap, OOM walls).
//!
//! Run: `cargo bench --bench table1_ondevice`

#[path = "bench_util.rs"]
#[allow(dead_code)]
mod bench_util;

use galaxy::baselines::full_footprint_mb;
use galaxy::metrics::{fmt_secs, Table};
use galaxy::model::{ModelConfig, ModelKind};
use galaxy::sim::{DeviceClass, DeviceSpec};

const SEQ: usize = 30;

fn local_latency(dev: &DeviceSpec, m: &ModelConfig) -> Option<f64> {
    galaxy::baselines::local(m, dev, SEQ).ok().map(|r| r.total_s())
}

fn main() {
    let nano_m = DeviceSpec::new(0, DeviceClass::NanoM);
    let a100 = DeviceSpec::new(0, DeviceClass::A100);

    let mut t = Table::new(
        "Table I — on-device inference latency & memory footprint (seq 30)",
        &["model", "Nano-M", "A100", "mem footprint", "paper Nano-M", "paper A100", "paper mem"],
    );
    let paper = [
        ("DistilBert", "0.37s", "5ms", "130MB"),
        ("Bert-L", "2.43s", "20ms", "680MB"),
        ("GPT2-L", "OOM", "29ms", "1.6GB"),
        ("OPT-L", "OOM", "27ms", "2.6GB"),
        ("OPT-XL", "OOM", "38ms", "5.4GB"),
    ];
    for (kind, (pname, pn, pa, pm)) in ModelKind::ALL_PAPER.iter().zip(paper.iter()) {
        let m = ModelConfig::by_kind(*kind);
        assert_eq!(m.kind.name(), *pname);
        let nano = match local_latency(&nano_m, &m) {
            Some(s) => fmt_secs(s),
            None => "OOM".into(),
        };
        let a = match local_latency(&a100, &m) {
            Some(s) => fmt_secs(s),
            None => "OOM".into(),
        };
        t.row(&[
            m.kind.name().into(),
            nano,
            a,
            format!("{:.0} MB", full_footprint_mb(&m, SEQ)),
            pn.to_string(),
            pa.to_string(),
            pm.to_string(),
        ]);
    }
    println!("{}", t.render());
    println!("note: Nano-M budget 1.5 GB; OOM reproduces the paper's memory wall.");
    // 121x headline: Bert-L Nano-M vs A100.
    let bert = ModelConfig::bert_large();
    if let (Some(n), Some(a)) = (local_latency(&nano_m, &bert), local_latency(&a100, &bert)) {
        println!("Bert-L Nano-M/A100 slowdown: {:.0}x (paper: 121x)", n / a);
    }
}
