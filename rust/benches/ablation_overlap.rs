//! Ablation: the tile-based communication/computation overlap (§III-D) —
//! simulated savings across bandwidths *and* real wall-clock on the PJRT
//! cluster (where overlap = channel transfers proceeding during PJRT
//! dispatch).
//!
//! Run: `cargo bench --bench ablation_overlap`

#[path = "bench_util.rs"]
#[allow(dead_code)]
mod bench_util;

use bench_util::{galaxy_report, time_n};
use galaxy::cluster::RealCluster;
use galaxy::config::{default_artifacts_dir, Manifest};
use galaxy::engine::{Engine, InferRequest};
use galaxy::metrics::Table;
use galaxy::model::{ModelConfig, ModelKind};
use galaxy::parallel::OverlapMode;
use galaxy::planner::Planner;
use galaxy::profiler::Profiler;
use galaxy::sim::{DeviceClass, EdgeEnv};

const SEQ: usize = 284;

fn main() {
    // --- simulated ablation -------------------------------------------
    let mut t = Table::new(
        "Ablation — tiled overlap vs serialized sync (simulated, env B)",
        &["model", "bandwidth", "serial", "tiled", "saved", "hidden comm"],
    );
    for kind in [ModelKind::BertLarge, ModelKind::Gpt2Large] {
        let model = ModelConfig::by_kind(kind);
        let env = EdgeEnv::preset_b();
        for mbps in [25.0, 125.0, 500.0] {
            let tiled = galaxy_report(&model, &env, mbps, SEQ, OverlapMode::Tiled).unwrap();
            let serial = galaxy_report(&model, &env, mbps, SEQ, OverlapMode::None).unwrap();
            t.row(&[
                model.kind.name().into(),
                format!("{mbps:.0} Mbps"),
                format!("{:.0} ms", serial.total_s() * 1e3),
                format!("{:.0} ms", tiled.total_s() * 1e3),
                format!("{:.1}%", 100.0 * (1.0 - tiled.total_s() / serial.total_s())),
                format!("{:.0} ms", tiled.hidden_comm_s * 1e3),
            ]);
        }
    }
    println!("{}", t.render());

    // --- real-path ablation (galaxy-mini over PJRT) --------------------
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("(artifacts not built; skipping real-path ablation)");
        return;
    }
    let model = ModelConfig::galaxy_mini();
    let manifest = Manifest::load(&dir).unwrap();
    let env = EdgeEnv::new("3x", &[DeviceClass::NanoM; 3]);
    let seq = manifest.seq_len;
    let profile = Profiler::analytic(&model, &env, seq).profile();
    let plan = Planner::new(&model, &env, &profile).plan().unwrap();
    let req = InferRequest::new(0, seq, seq);

    let mut t2 = Table::new(
        "Ablation — real PJRT cluster (galaxy-mini, 3 workers, 20 reqs)",
        &["mode", "mean", "best", "pjrt calls/req"],
    );
    for overlap in [OverlapMode::None, OverlapMode::Tiled] {
        let mut cluster =
            RealCluster::spawn(&model, &manifest, &plan, overlap, "xla", 42).unwrap();
        {
            let engine: &mut dyn Engine = &mut cluster;
            engine.infer(&req).unwrap(); // warm
        }
        cluster.reset_report(); // scope measurement after lazy compiles
        let engine: &mut dyn Engine = &mut cluster;
        let (mean, best) = time_n(20, || {
            engine.infer(&req).unwrap();
        });
        let rep = cluster.report();
        let calls = rep.pjrt_calls / rep.requests as u64;
        t2.row(&[
            overlap.name().into(),
            format!("{:.1} ms", mean * 1e3),
            format!("{:.1} ms", best * 1e3),
            format!("{calls}"),
        ]);
    }
    println!("{}", t2.render());
}
