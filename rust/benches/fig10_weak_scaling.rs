//! Regenerates **paper Fig. 10**: weak scaling — fixed 96 tokens *per
//! device*, single Transformer layer (to dodge OOM, as the paper does),
//! 1000 Mbps, 1–4 Jetson Nano-M. Reports aggregate FLOPS and the
//! percentage of linear scaling (paper: 81% GPT2-L, 86% OPT-XL at 4-way).
//!
//! Run: `cargo bench --bench fig10_weak_scaling`

#[path = "bench_util.rs"]
#[allow(dead_code)]
mod bench_util;

use bench_util::galaxy_latency;
use galaxy::metrics::Table;
use galaxy::model::{ModelConfig, ModelKind};
use galaxy::sim::{DeviceClass, EdgeEnv};

const MBPS: f64 = 1000.0;
const SEQ_PER_DEVICE: usize = 96;

fn main() {
    for kind in [ModelKind::Gpt2Large, ModelKind::OptXl] {
        let mut model = ModelConfig::by_kind(kind);
        model.layers = 1; // paper: load a single layer, loop inference
        let mut t = Table::new(
            format!("Fig 10 — weak scaling, {} single layer (96 tokens/device, 1000 Mbps)", model.kind.name()),
            &["devices", "seq", "latency/layer", "GFLOPS", "% of linear"],
        );
        let mut base_flops = 0.0;
        for d in 1..=4usize {
            let env = EdgeEnv::new(format!("{d}x"), &vec![DeviceClass::NanoM; d]);
            let seq = SEQ_PER_DEVICE * d;
            let lat = galaxy_latency(&model, &env, MBPS, seq).expect("single layer fits");
            let gflops = model.total_flops(seq) as f64 / lat / 1e9;
            if d == 1 {
                base_flops = gflops;
            }
            let linear = base_flops * d as f64;
            t.row(&[
                format!("{d}"),
                format!("{seq}"),
                format!("{:.1} ms", lat * 1e3),
                format!("{gflops:.2}"),
                format!("{:.0}%", 100.0 * gflops / linear),
            ]);
        }
        println!("{}", t.render());
    }
    println!("paper: 4-way weak scaling reaches 81% (GPT2-L) / 86% (OPT-XL) of linear.");
}
