//! Regenerates **paper Fig. 9**: latency on heterogeneous edge
//! environments D/E/F (mixed Nano-L/M/S with skewed memory budgets) at
//! 125 Mbps — where heterogeneity- and memory-aware planning buys Galaxy
//! its largest wins (paper: 1.3x–2.5x).
//!
//! Run: `cargo bench --bench fig9_heterogeneous`

#[path = "bench_util.rs"]
#[allow(dead_code)]
mod bench_util;

use bench_util::{baseline_latency, galaxy_latency, galaxy_plan, speedup_cell};
use galaxy::baselines::BaselineKind;
use galaxy::metrics::{fmt_secs, Table};
use galaxy::model::{ModelConfig, ModelKind};
use galaxy::sim::EdgeEnv;

const MBPS: f64 = 125.0;
const SEQ: usize = 284;

fn main() {
    let mut speedups: Vec<f64> = Vec::new();
    for env in [EdgeEnv::preset_d(), EdgeEnv::preset_e(), EdgeEnv::preset_f()] {
        let mut t = Table::new(
            format!(
                "Fig 9 — heterogeneous env {} ({})",
                env.name,
                env.devices
                    .iter()
                    .map(|d| format!("{}@{:.1}GB", d.class.name(), d.budget_mb / 1000.0))
                    .collect::<Vec<_>>()
                    .join(" + ")
            ),
            &["model", "Galaxy", "M-LM", "SP", "vs M-LM", "vs SP", "galaxy heads"],
        );
        for kind in [ModelKind::DistilBert, ModelKind::BertLarge, ModelKind::Gpt2Large, ModelKind::OptLarge] {
            let model = ModelConfig::by_kind(kind);
            let g = galaxy_latency(&model, &env, MBPS, SEQ);
            let m = baseline_latency(BaselineKind::MegatronLm, &model, &env, MBPS, SEQ);
            let s = baseline_latency(BaselineKind::SeqPar, &model, &env, MBPS, SEQ);
            if let (Some(gv), Some(mv)) = (g, m) {
                speedups.push(mv / gv);
            }
            let heads = galaxy_plan(&model, &env, SEQ)
                .map(|p| format!("{:?}", p.partition.heads))
                .unwrap_or_else(|| "-".into());
            let cell = |v: Option<f64>| v.map(fmt_secs).unwrap_or_else(|| "OOM".into());
            t.row(&[
                model.kind.name().into(),
                cell(g),
                cell(m),
                cell(s),
                speedup_cell(g, m),
                speedup_cell(g, s),
                heads,
            ]);
        }
        println!("{}", t.render());
    }
    if !speedups.is_empty() {
        let min = speedups.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = speedups.iter().cloned().fold(0.0, f64::max);
        println!("Galaxy vs M-LM speedup range: {min:.2}x – {max:.2}x (paper: 1.3x – 2.5x)");
    }
}
