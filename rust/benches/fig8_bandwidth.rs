//! Regenerates **paper Fig. 8**: end-to-end latency of Galaxy vs M-LM vs
//! SP under five simulated D2D bandwidths — the series behind the paper's
//! 1.04x–1.45x reduction claim across network conditions.
//!
//! Run: `cargo bench --bench fig8_bandwidth`

#[path = "bench_util.rs"]
#[allow(dead_code)]
mod bench_util;

use bench_util::{baseline_latency, galaxy_latency};
use galaxy::baselines::BaselineKind;
use galaxy::metrics::{fmt_secs, Table};
use galaxy::model::{ModelConfig, ModelKind};
use galaxy::sim::EdgeEnv;

const SEQ: usize = 284;
const BANDWIDTHS: [f64; 5] = [25.0, 50.0, 125.0, 250.0, 500.0];

fn main() {
    for (kind, env) in [
        (ModelKind::DistilBert, EdgeEnv::preset_a()),
        (ModelKind::BertLarge, EdgeEnv::preset_a()),
        (ModelKind::BertLarge, EdgeEnv::preset_b()),
        (ModelKind::Gpt2Large, EdgeEnv::preset_b()),
        (ModelKind::OptLarge, EdgeEnv::preset_c()),
    ] {
        let model = ModelConfig::by_kind(kind);
        let mut t = Table::new(
            format!("Fig 8 — {} on env {} (latency vs bandwidth)", model.kind.name(), env.name),
            &["bandwidth", "Galaxy", "M-LM", "SP", "Galaxy speedup vs best baseline"],
        );
        for mbps in BANDWIDTHS {
            let g = galaxy_latency(&model, &env, mbps, SEQ);
            let m = baseline_latency(BaselineKind::MegatronLm, &model, &env, mbps, SEQ);
            let s = baseline_latency(BaselineKind::SeqPar, &model, &env, mbps, SEQ);
            let best = [m, s].into_iter().flatten().fold(f64::INFINITY, f64::min);
            let cell = |v: Option<f64>| v.map(fmt_secs).unwrap_or_else(|| "OOM".into());
            t.row(&[
                format!("{mbps:.0} Mbps"),
                cell(g),
                cell(m),
                cell(s),
                match g {
                    Some(gv) if best.is_finite() => format!("{:.2}x", best / gv),
                    _ => "-".into(),
                },
            ]);
        }
        println!("{}", t.render());
    }
    println!("paper claim: 1.04x–1.45x latency reduction across bandwidths/models.");
}
