//! §Bench trajectory harness: regenerates the committed `BENCH_*.json`
//! files at the repository root — machine-readable snapshots of the three
//! raw-speed surfaces the quantized-wire work optimizes:
//!
//! * `BENCH_transport.json`  — threaded-link AG-walk throughput per wire
//!   format (tiles/s, wire MB/s) and encode-pool hit rate;
//! * `BENCH_sim_engine.json` — `SimEngine` request throughput (wall
//!   clock) plus the modeled per-format latency/exposed-comm numbers at
//!   the paper's 25 Mbps low-bandwidth point;
//! * `BENCH_scheduler.json`  — scheduler dispatch overhead per request on
//!   a seeded replay trace (the sim engine resolves instantly in wall
//!   clock, so wall time is pure scheduler bookkeeping);
//! * `BENCH_overlap.json`    — measured per-post ring overhead (the
//!   calibration input behind `NetParams::per_post_overhead_s`) and the
//!   planner's modeled per-format overlap grain choice at 25 Mbps;
//! * `BENCH_decode.json`     — generative decode on a seeded trace:
//!   modeled TTFT/TPOT and token throughput for the token-level
//!   continuous batcher against the serial-decode baseline, plus the
//!   wall-clock scheduler bookkeeping cost per generated token.
//!
//! Run:   `cargo bench --bench bench_report`          (full, rewrites JSON)
//! Smoke: `GALAXY_BENCH_SMOKE=1 cargo bench --bench bench_report`
//!        (fewer iterations; exits non-zero when a throughput metric
//!        regresses more than 25% against the committed baselines —
//!        the CI gate. See BENCH.md for the schema.)

#[path = "bench_util.rs"]
#[allow(dead_code)]
mod bench_util;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;

use galaxy::config::json::Json;
use galaxy::engine::{Engine, InferRequest};
use galaxy::model::ModelConfig;
use galaxy::parallel::overlap::all_gather_steps;
use galaxy::planner::{Deployment, Planner};
use galaxy::profiler::Profiler;
use galaxy::serving::{Policy, Scheduler, SchedulerConfig};
use galaxy::sim::{EdgeEnv, NetParams, SimEngine};
use galaxy::tensor::Tensor2;
use galaxy::testkit::{Arrival, TraceGen};
use galaxy::transport::{self, WireFormat};

/// The low-bandwidth point where the wire format matters most (paper
/// Fig. 8 leftmost column; the trajectory tracks it per commit).
const MBPS: f64 = 25.0;
const SEQ: usize = 284;

fn main() {
    let smoke = std::env::var("GALAXY_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    let root = repo_root();
    let mut failures: Vec<String> = Vec::new();

    let transport_json = bench_transport(smoke, &root, &mut failures);
    let sim_json = bench_sim_engine(smoke, &root, &mut failures);
    let sched_json = bench_scheduler(smoke, &root, &mut failures);
    let overlap_json = bench_overlap(smoke, &root, &mut failures);
    let decode_json = bench_decode(smoke, &root, &mut failures);

    write_report(&root.join("BENCH_transport.json"), &transport_json);
    write_report(&root.join("BENCH_sim_engine.json"), &sim_json);
    write_report(&root.join("BENCH_scheduler.json"), &sched_json);
    write_report(&root.join("BENCH_overlap.json"), &overlap_json);
    write_report(&root.join("BENCH_decode.json"), &decode_json);

    if !failures.is_empty() {
        eprintln!("bench regression gate FAILED (>25% vs committed baseline):");
        for f in &failures {
            eprintln!("  - {f}");
        }
        std::process::exit(1);
    }
    println!(
        "bench trajectory written: BENCH_transport.json BENCH_sim_engine.json \
         BENCH_scheduler.json BENCH_overlap.json BENCH_decode.json"
    );
}

fn repo_root() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("..")
}

// ---- transport -----------------------------------------------------------

/// AG-walk a 2-device threaded ring `rounds` times per format and report
/// wire throughput plus the encode-pool hit rate.
fn bench_transport(smoke: bool, root: &Path, failures: &mut Vec<String>) -> Json {
    let rounds: usize = if smoke { 60 } else { 400 };
    let (tile_rows, tile_cols) = (128usize, 768usize);
    let baseline = read_json(&root.join("BENCH_transport.json"));

    let mut formats = BTreeMap::new();
    for format in WireFormat::all() {
        let d = 2usize;
        let t0 = std::time::Instant::now();
        let ring = transport::threaded_ring_with(d, format).expect("threaded ring");
        let handles: Vec<_> = ring
            .into_iter()
            .enumerate()
            .map(|(i, mut io)| {
                std::thread::spawn(move || {
                    let steps = all_gather_steps(i, d);
                    let my = Arc::new(Tensor2::full(tile_rows, tile_cols, 0.5 + i as f32));
                    for _ in 0..rounds {
                        let mut tiles: Vec<Option<Arc<Tensor2>>> = vec![None; d];
                        tiles[i] = Some(my.clone());
                        io.ag_walk(&steps, &mut tiles, |_, _| Ok(Some(())))
                            .expect("ag walk");
                    }
                    (io.bytes, io.pool_stats().expect("pool stats"))
                })
            })
            .collect();
        let mut wire_bytes = 0u64;
        let (mut hits, mut allocs) = (0u64, 0u64);
        for h in handles {
            let (b, p) = h.join().expect("transport bench thread");
            wire_bytes += b;
            hits += p.hits;
            allocs += p.allocs;
        }
        let secs = t0.elapsed().as_secs_f64().max(1e-9);
        let tiles_moved = (d * (d - 1) * rounds) as f64;
        let wire_mb_per_s = wire_bytes as f64 / 1e6 / secs;
        let hit_rate = if hits + allocs == 0 { 1.0 } else { hits as f64 / (hits + allocs) as f64 };

        gate(
            failures,
            &format!("transport {format} wire MB/s"),
            metric(baseline.as_ref(), &["formats", format.name(), "wire_mb_per_s"]),
            wire_mb_per_s,
        );
        formats.insert(
            format.name().to_string(),
            obj(vec![
                ("elem_bytes", Json::Num(format.elem_bytes() as f64)),
                ("wire_mb", Json::Num(round3(wire_bytes as f64 / 1e6))),
                ("wire_mb_per_s", Json::Num(round3(wire_mb_per_s))),
                ("tiles_per_s", Json::Num(round3(tiles_moved / secs))),
                ("pool_hit_rate", Json::Num(round3(hit_rate))),
            ]),
        );
    }

    obj(vec![
        ("bench", Json::Str("transport".into())),
        ("schema_version", Json::Num(1.0)),
        ("mode", Json::Str(if smoke { "smoke" } else { "full" }.into())),
        ("rounds", Json::Num(rounds as f64)),
        ("tile_rows", Json::Num(tile_rows as f64)),
        ("tile_cols", Json::Num(tile_cols as f64)),
        ("formats", Json::Obj(formats)),
    ])
}

// ---- sim engine ----------------------------------------------------------

/// Wall-clock `SimEngine::infer` throughput plus the modeled per-format
/// trajectory at the 25 Mbps point (Bert-L on the heterogeneous preset B).
fn bench_sim_engine(smoke: bool, root: &Path, failures: &mut Vec<String>) -> Json {
    let iters: usize = if smoke { 8 } else { 40 };
    let baseline = read_json(&root.join("BENCH_sim_engine.json"));

    let model = ModelConfig::bert_large();
    let env = EdgeEnv::preset_b();
    let profile = Profiler::analytic(&model, &env, SEQ).profile();
    let plan = Planner::new(&model, &env, &profile).plan().expect("bert-l fits preset B");

    let mut formats = BTreeMap::new();
    let mut f32_rps = 0.0f64;
    for format in WireFormat::all() {
        let mut sim = SimEngine::new(&model, &env, plan.clone(), NetParams::mbps(MBPS))
            .with_wire_format(format);
        let req = InferRequest::new(0, SEQ, SEQ);
        let outcome = {
            let engine: &mut dyn Engine = &mut sim;
            engine.infer(&req).expect("sim infer")
        };
        let (mean_s, _best) = bench_util::time_n(iters, || {
            let engine: &mut dyn Engine = &mut sim;
            engine.infer(&req).expect("sim infer");
        });
        // Throughput is the *modeled* rate: the harness loop resolves
        // instantly in wall clock, so 1/mean_s would report the same
        // iteration rate for every wire format (it once did — the wall
        // rate is kept separately as `harness_infer_per_s`, ungated).
        let rps = 1.0 / outcome.total_s().max(1e-12);
        if format == WireFormat::F32 {
            f32_rps = rps;
        }
        formats.insert(
            format.name().to_string(),
            obj(vec![
                ("requests_per_s", Json::Num(round6(rps))),
                ("harness_infer_per_s", Json::Num(round3(1.0 / mean_s.max(1e-12)))),
                ("modeled_total_s", Json::Num(round6(outcome.total_s()))),
                ("modeled_exposed_comm_s", Json::Num(round6(outcome.exposed_comm_s))),
                ("modeled_hidden_comm_s", Json::Num(round6(outcome.hidden_comm_s))),
                ("ring_mb", Json::Num(round3(outcome.ring_bytes as f64 / 1e6))),
            ]),
        );
    }
    gate(
        failures,
        "sim_engine f32 requests/s",
        metric(baseline.as_ref(), &["formats", "f32", "requests_per_s"]),
        f32_rps,
    );

    obj(vec![
        ("bench", Json::Str("sim_engine".into())),
        ("schema_version", Json::Num(1.0)),
        ("mode", Json::Str(if smoke { "smoke" } else { "full" }.into())),
        ("model", Json::Str("bert-l".into())),
        ("env", Json::Str("B".into())),
        ("mbps", Json::Num(MBPS)),
        ("seq", Json::Num(SEQ as f64)),
        ("iters", Json::Num(iters as f64)),
        ("formats", Json::Obj(formats)),
    ])
}

// ---- scheduler -----------------------------------------------------------

/// Scheduler bookkeeping overhead on a seeded replay trace. The simulated
/// engine returns instantly in wall clock, so elapsed wall time per
/// request is dispatch overhead (queue ops, bucketing, batching, metric
/// accumulation), not model execution.
fn bench_scheduler(smoke: bool, root: &Path, failures: &mut Vec<String>) -> Json {
    let n_requests: usize = 48;
    let reps: usize = if smoke { 2 } else { 10 };
    let baseline = read_json(&root.join("BENCH_scheduler.json"));

    let model = ModelConfig::bert_large();
    let env = EdgeEnv::preset_b();
    let profile = Profiler::analytic(&model, &env, 512).profile();
    let plan = Planner::new(&model, &env, &profile).plan().expect("bert-l fits preset B");
    let trace = TraceGen::new(7)
        .arrivals(Arrival::Poisson { rate_rps: 2.0 })
        .lengths(&[(0.2, 64, 180), (0.6, 200, 360), (0.2, 380, 512)])
        .requests(n_requests);

    let mut last_report = None;
    let (mean_s, _best) = bench_util::time_n(reps, || {
        let engine = SimEngine::new(&model, &env, plan.clone(), NetParams::mbps(MBPS));
        let cfg = SchedulerConfig {
            policy: Policy::Fifo,
            slo_s: 30.0,
            max_in_flight: 0,
            ..Default::default()
        };
        last_report = Some(Scheduler::with_config(engine, cfg).run(&trace).expect("replay"));
    });
    let report = last_report.expect("at least one timed run");
    let overhead_us = mean_s * 1e6 / n_requests as f64;
    let dispatch_rps = n_requests as f64 / mean_s.max(1e-12);

    gate(
        failures,
        "scheduler dispatch requests/s",
        metric(baseline.as_ref(), &["dispatch_requests_per_s"]),
        dispatch_rps,
    );

    obj(vec![
        ("bench", Json::Str("scheduler".into())),
        ("schema_version", Json::Num(1.0)),
        ("mode", Json::Str(if smoke { "smoke" } else { "full" }.into())),
        ("requests", Json::Num(n_requests as f64)),
        ("rate_rps", Json::Num(2.0)),
        ("seed", Json::Num(7.0)),
        ("reps", Json::Num(reps as f64)),
        ("dispatch_overhead_us_per_req", Json::Num(round3(overhead_us))),
        ("dispatch_requests_per_s", Json::Num(round3(dispatch_rps))),
        ("modeled_wall_span_s", Json::Num(round6(report.metrics.wall_span_s))),
        ("modeled_service_p95_s", Json::Num(round6(report.metrics.service.p95_s()))),
        ("served", Json::Num(report.served() as f64)),
    ])
}

// ---- overlap granularity -------------------------------------------------

/// Calibrate the per-post ring overhead with a tiny-tile AG walk (the
/// wire volume of a 2x8 tile is negligible, so walk time is post/consume
/// bookkeeping — the real-world counterpart of
/// `NetParams::per_post_overhead_s`), then record the planner's modeled
/// grain choice per wire format at the 25 Mbps point. Finer grains pay
/// the measured overhead once per micro-tile; the chooser trades it
/// against exposed communication.
fn bench_overlap(smoke: bool, root: &Path, failures: &mut Vec<String>) -> Json {
    let rounds: usize = if smoke { 100 } else { 1000 };
    let baseline = read_json(&root.join("BENCH_overlap.json"));

    let d = 2usize;
    let t0 = std::time::Instant::now();
    let ring = transport::threaded_ring_with(d, WireFormat::F32).expect("threaded ring");
    let handles: Vec<_> = ring
        .into_iter()
        .enumerate()
        .map(|(i, mut io)| {
            std::thread::spawn(move || {
                let steps = all_gather_steps(i, d);
                let my = Arc::new(Tensor2::full(2, 8, i as f32));
                for _ in 0..rounds {
                    let mut tiles: Vec<Option<Arc<Tensor2>>> = vec![None; d];
                    tiles[i] = Some(my.clone());
                    io.ag_walk(&steps, &mut tiles, |_, _| Ok(Some(()))).expect("ag walk");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().expect("overlap bench thread");
    }
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    // Each device posts (d - 1) tiles per walk; walks run concurrently,
    // so the round-trip cost per post is wall time over posts-per-device.
    let posts = (rounds * (d - 1)) as f64;
    let per_post_s = secs / posts;
    let posts_per_s = posts / secs;

    gate(failures, "overlap posts/s", metric(baseline.as_ref(), &["posts_per_s"]), posts_per_s);

    // Modeled grain choice per wire format. The chooser runs with the
    // default modeled per-post overhead (not the measured one) so the
    // committed trajectory stays machine-independent; the measured
    // number above is the calibration evidence for that default.
    let model = ModelConfig::bert_large();
    let env = EdgeEnv::preset_b();
    let profile = Profiler::analytic(&model, &env, SEQ).profile();
    let plan = Planner::new(&model, &env, &profile).plan().expect("bert-l fits preset B");
    let net = NetParams::mbps(MBPS);
    let mut formats = BTreeMap::new();
    for format in WireFormat::all() {
        let mut dep = Deployment::from_plan(plan.clone(), &[SEQ]);
        dep.choose_tile_grains(&model, &env, net, format).expect("grain chooser");
        let rung = &dep.rungs()[0];
        let choice = rung.grain_choice.expect("chooser records a choice");
        formats.insert(
            format.name().to_string(),
            obj(vec![
                ("chosen_grain", Json::Num(rung.tile_grain as f64)),
                ("modeled_exposed_comm_s", Json::Num(round6(choice.exposed_s))),
                ("baseline_exposed_comm_s", Json::Num(round6(choice.baseline_exposed_s))),
                ("grain_overhead_s", Json::Num(round6(choice.overhead_s))),
            ]),
        );
    }

    obj(vec![
        ("bench", Json::Str("overlap".into())),
        ("schema_version", Json::Num(1.0)),
        ("mode", Json::Str(if smoke { "smoke" } else { "full" }.into())),
        ("rounds", Json::Num(rounds as f64)),
        ("per_post_overhead_us", Json::Num(round3(per_post_s * 1e6))),
        ("posts_per_s", Json::Num(round3(posts_per_s))),
        ("model", Json::Str("bert-l".into())),
        ("env", Json::Str("B".into())),
        ("mbps", Json::Num(MBPS)),
        ("seq", Json::Num(SEQ as f64)),
        ("formats", Json::Obj(formats)),
    ])
}

// ---- generative decode ---------------------------------------------------

/// Generative decode on a seeded trace: the same replay run through the
/// token-level continuous batcher and through the serial-decode baseline.
/// The committed trajectory tracks the *modeled* numbers (TTFT p95, TPOT,
/// tokens/s — deterministic per commit, machine-independent); the
/// wall-clock bookkeeping cost per generated token rides along ungated.
fn bench_decode(smoke: bool, root: &Path, failures: &mut Vec<String>) -> Json {
    let n_requests: usize = 32;
    let reps: usize = if smoke { 2 } else { 10 };
    let baseline = read_json(&root.join("BENCH_decode.json"));

    let model = ModelConfig::bert_large();
    let env = EdgeEnv::preset_b();
    let profile = Profiler::analytic(&model, &env, 512).profile();
    let plan = Planner::new(&model, &env, &profile).plan().expect("bert-l fits preset B");
    let trace = TraceGen::new(11)
        .arrivals(Arrival::Poisson { rate_rps: 4.0 })
        .lengths(&[(1.0, 64, 200)])
        .generative(&[(1.0, 8, 24)])
        .requests(n_requests);

    let mut run = |token_batching: bool| {
        let mut last = None;
        let (mean_s, _best) = bench_util::time_n(reps, || {
            let engine = SimEngine::new(&model, &env, plan.clone(), NetParams::mbps(MBPS))
                .with_buckets(vec![128, 256, 512])
                .with_max_batch(4);
            let cfg = SchedulerConfig {
                policy: Policy::Fifo,
                slo_s: 600.0,
                max_in_flight: 0,
                token_batching,
                ..Default::default()
            };
            last = Some(Scheduler::with_config(engine, cfg).run(&trace).expect("replay"));
        });
        (last.expect("at least one timed run"), mean_s)
    };
    let (batched, wall_s) = run(true);
    let (serial, _) = run(false);

    let mode_json = |r: &galaxy::serving::SchedReport, wall: Option<f64>| {
        let mut pairs = vec![
            ("ttft_p95_s", Json::Num(round6(r.metrics.ttft.p95_s()))),
            ("ttft_mean_s", Json::Num(round6(r.metrics.ttft.mean_s()))),
            ("tpot_mean_s", Json::Num(round6(r.metrics.tpot.mean_s()))),
            ("modeled_tokens_per_s", Json::Num(round3(r.metrics.tokens_per_s()))),
            ("generated_tokens", Json::Num(r.metrics.generated_tokens as f64)),
            ("modeled_wall_span_s", Json::Num(round6(r.metrics.wall_span_s))),
        ];
        if let Some(w) = wall {
            let per_token_us = w * 1e6 / (r.metrics.generated_tokens as f64).max(1.0);
            pairs.push(("dispatch_overhead_us_per_token", Json::Num(round3(per_token_us))));
        }
        obj(pairs)
    };

    gate(
        failures,
        "decode batched tokens/s",
        metric(baseline.as_ref(), &["batched", "modeled_tokens_per_s"]),
        batched.metrics.tokens_per_s(),
    );

    let speedup = serial.metrics.ttft.p95_s() / batched.metrics.ttft.p95_s().max(1e-12);
    obj(vec![
        ("bench", Json::Str("decode".into())),
        ("schema_version", Json::Num(1.0)),
        ("mode", Json::Str(if smoke { "smoke" } else { "full" }.into())),
        ("model", Json::Str("bert-l".into())),
        ("env", Json::Str("B".into())),
        ("mbps", Json::Num(MBPS)),
        ("requests", Json::Num(n_requests as f64)),
        ("seed", Json::Num(11.0)),
        ("max_batch", Json::Num(4.0)),
        ("reps", Json::Num(reps as f64)),
        ("batched", mode_json(&batched, Some(wall_s))),
        ("serial", mode_json(&serial, None)),
        ("batched_ttft_p95_speedup", Json::Num(round3(speedup))),
    ])
}

// ---- harness plumbing ----------------------------------------------------

fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn round3(x: f64) -> f64 {
    (x * 1e3).round() / 1e3
}

fn round6(x: f64) -> f64 {
    (x * 1e6).round() / 1e6
}

fn read_json(path: &Path) -> Option<Json> {
    std::fs::read_to_string(path).ok().and_then(|s| Json::parse(&s).ok())
}

/// Walk `path` through nested objects; `None` when absent (bootstrap).
fn metric(j: Option<&Json>, path: &[&str]) -> Option<f64> {
    let mut cur = j?;
    for k in path {
        cur = cur.get(k).ok()?;
    }
    cur.as_f64().ok()
}

/// Throughput regression gate: fail when `measured` drops more than 25%
/// below the committed baseline. Missing baselines bootstrap silently
/// (first run on a new machine class regenerates them).
fn gate(failures: &mut Vec<String>, name: &str, baseline: Option<f64>, measured: f64) {
    let smoke = std::env::var("GALAXY_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false);
    if !smoke {
        return; // full runs rewrite the trajectory, they don't gate on it
    }
    if let Some(base) = baseline {
        if base > 0.0 && measured < base * 0.75 {
            failures.push(format!("{name}: {measured:.3} < 75% of baseline {base:.3}"));
        }
    } else {
        eprintln!("note: no committed baseline for `{name}` — gate skipped");
    }
}

fn write_report(path: &Path, json: &Json) {
    std::fs::write(path, json.to_string() + "\n")
        .unwrap_or_else(|e| panic!("write {}: {e}", path.display()));
    println!("wrote {}", path.display());
}
