//! Regenerates **paper Fig. 11**: strong scaling — fixed global workload
//! (seq 384), single layer, 1000 Mbps, 1–4 Nano-M. Reports per-layer
//! latency and the reduction vs Local (paper: 3.05x GPT2-L / 3.24x OPT-XL
//! at 4 devices).
//!
//! Run: `cargo bench --bench fig11_strong_scaling`

#[path = "bench_util.rs"]
#[allow(dead_code)]
mod bench_util;

use bench_util::galaxy_latency;
use galaxy::metrics::Table;
use galaxy::model::{ModelConfig, ModelKind};
use galaxy::sim::{DeviceClass, DeviceSpec, EdgeEnv};

const MBPS: f64 = 1000.0;
const SEQ: usize = 384;

fn main() {
    for kind in [ModelKind::Gpt2Large, ModelKind::OptXl] {
        let mut model = ModelConfig::by_kind(kind);
        model.layers = 1;
        // Local reference: one Nano-M running the full layer (no memory
        // gate — the paper loads a single layer precisely to avoid OOM).
        let dev = DeviceSpec::new(0, DeviceClass::NanoM);
        let local = dev.mha_time(&model, SEQ, model.heads)
            + dev.mlp_time(&model, SEQ, model.heads)
            + 2.0 * dev.connective_time(&model, SEQ);
        let mut t = Table::new(
            format!("Fig 11 — strong scaling, {} single layer (seq 384, 1000 Mbps)", model.kind.name()),
            &["devices", "latency/layer", "speedup vs Local"],
        );
        t.row(&["1 (Local)".into(), format!("{:.1} ms", local * 1e3), "1.00x".into()]);
        for d in 2..=4usize {
            let env = EdgeEnv::new(format!("{d}x"), &vec![DeviceClass::NanoM; d]);
            let lat = galaxy_latency(&model, &env, MBPS, SEQ).expect("single layer fits");
            t.row(&[
                format!("{d}"),
                format!("{:.1} ms", lat * 1e3),
                format!("{:.2}x", local / lat),
            ]);
        }
        println!("{}", t.render());
    }
    println!("paper: 4-way strong scaling cuts per-layer latency 3.05x (GPT2-L) / 3.24x (OPT-XL).");
}
