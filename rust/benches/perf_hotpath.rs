//! §Perf hot-path bench: the real PJRT request path of `galaxy serve` —
//! end-to-end latency distribution, PJRT dispatch counts, and ring
//! traffic, per device count and artifact flavor. This is the bench the
//! EXPERIMENTS.md §Perf iteration log is measured with.
//!
//! The distributed cases drive the cluster through the unified `Engine`
//! trait (the same surface the serving scheduler uses); the single-device
//! `LocalRunner` stays tensor-level as the non-engine oracle.
//!
//! Run: `cargo bench --bench perf_hotpath`

#[path = "bench_util.rs"]
#[allow(dead_code)]
mod bench_util;

use galaxy::cluster::{local::LocalRunner, RealCluster};
use galaxy::config::{default_artifacts_dir, Manifest};
use galaxy::engine::{Engine, InferRequest};
use galaxy::metrics::{LatencyStats, Table};
use galaxy::model::{ModelConfig, WeightGen};
use galaxy::parallel::OverlapMode;
use galaxy::planner::Planner;
use galaxy::profiler::Profiler;
use galaxy::sim::{DeviceClass, EdgeEnv};

const REQS: usize = 12;

fn main() {
    let dir = default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        println!("artifacts not built — run `make artifacts` first");
        return;
    }
    let model = ModelConfig::galaxy_mini();
    let manifest = Manifest::load(&dir).unwrap();
    let seq = manifest.seq_len;

    let mut t = Table::new(
        format!("§Perf — galaxy-mini request hot path ({REQS} reqs, seq {seq})"),
        &["config", "mean", "p95", "best", "pjrt/req", "ring MB/req"],
    );

    // Local single-runtime reference (non-engine numerics oracle).
    {
        let gen = WeightGen::new(&model, 42);
        let x = gen.input(0, seq);
        let mask = vec![0.0f32; seq];
        let mut local = LocalRunner::new(&model, &manifest, "xla", 42).unwrap();
        local.infer(&x, &mask).unwrap();
        let mut stats = LatencyStats::default();
        for _ in 0..REQS {
            let t0 = std::time::Instant::now();
            local.infer(&x, &mask).unwrap();
            stats.record(t0.elapsed().as_secs_f64());
        }
        t.row(&[
            "local (1 runtime)".into(),
            format!("{:.2} ms", stats.mean_s() * 1e3),
            format!("{:.2} ms", stats.p95_s() * 1e3),
            format!("{:.2} ms", stats.min_s() * 1e3),
            format!("{}", model.layers),
            "0.00".into(),
        ]);
    }

    for d in [2usize, 3, 4] {
        for flavor in ["xla", "pallas"] {
            let overlap = OverlapMode::Tiled;
            if flavor == "pallas" && overlap == OverlapMode::Tiled {
                // pallas tiles are not lowered (DESIGN.md); fused mode only.
                continue;
            }
            run_case(&model, &manifest, d, overlap, flavor, &mut t);
        }
        run_case(&model, &manifest, d, OverlapMode::None, "pallas", &mut t);
    }
    println!("{}", t.render());
}

fn run_case(
    model: &ModelConfig,
    manifest: &Manifest,
    d: usize,
    overlap: OverlapMode,
    flavor: &str,
    t: &mut Table,
) {
    let seq = manifest.seq_len;
    let env = EdgeEnv::new(format!("{d}x"), &vec![DeviceClass::NanoM; d]);
    let profile = Profiler::analytic(model, &env, seq).profile();
    let plan = Planner::new(model, &env, &profile).plan().unwrap();
    let mut cluster = RealCluster::spawn(model, manifest, &plan, overlap, flavor, 42).unwrap();
    let req = InferRequest::new(0, seq, seq);
    {
        let engine: &mut dyn Engine = &mut cluster;
        engine.infer(&req).unwrap(); // warm-up (compiles are lazy)
    }
    cluster.reset_report(); // scope the measurement window
    let engine: &mut dyn Engine = &mut cluster;
    let mut stats = LatencyStats::default();
    let mut calls = 0u64;
    let mut bytes = 0u64;
    for _ in 0..REQS {
        let outcome = engine.infer(&req).unwrap();
        stats.record(outcome.service_s);
        calls += outcome.pjrt_calls;
        bytes += outcome.ring_bytes;
    }
    t.row(&[
        format!("{d}w {} {}", flavor, overlap.name()),
        format!("{:.2} ms", stats.mean_s() * 1e3),
        format!("{:.2} ms", stats.p95_s() * 1e3),
        format!("{:.2} ms", stats.min_s() * 1e3),
        format!("{}", calls / REQS as u64),
        format!("{:.2}", bytes as f64 / REQS as f64 / 1e6),
    ]);
}
