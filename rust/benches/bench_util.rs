//! Shared helpers for the bench harness (included via `#[path]` from each
//! bench binary; the offline registry has no criterion, so benches are
//! plain `harness = false` mains printing paper-style tables).
//!
//! All Galaxy HMP runs go through the unified [`Engine`] trait — benches
//! never dispatch on a concrete engine type.

use galaxy::baselines::{self, BaselineKind};
use galaxy::engine::{Engine, InferOutcome, InferRequest};
use galaxy::model::ModelConfig;
use galaxy::parallel::OverlapMode;
use galaxy::planner::{Plan, Planner};
use galaxy::profiler::Profiler;
use galaxy::sim::{EdgeEnv, NetParams, SimEngine};

/// Run a prepared plan on the simulated backend through the engine trait.
pub fn plan_outcome(
    model: &ModelConfig,
    env: &EdgeEnv,
    plan: Plan,
    mbps: f64,
    seq: usize,
    overlap: OverlapMode,
) -> InferOutcome {
    let mut sim = SimEngine::new(model, env, plan, NetParams::mbps(mbps)).with_overlap(overlap);
    let engine: &mut dyn Engine = &mut sim;
    engine
        .infer(&InferRequest::new(0, seq, seq))
        .expect("simulated engines are infallible")
}

/// Galaxy's simulated end-to-end outcome; `None` on OOM/infeasible.
pub fn galaxy_report(
    model: &ModelConfig,
    env: &EdgeEnv,
    mbps: f64,
    seq: usize,
    overlap: OverlapMode,
) -> Option<InferOutcome> {
    let plan = galaxy_plan(model, env, seq)?;
    Some(plan_outcome(model, env, plan, mbps, seq, overlap))
}

pub fn galaxy_plan(model: &ModelConfig, env: &EdgeEnv, seq: usize) -> Option<Plan> {
    let profile = Profiler::analytic(model, env, seq).profile();
    Planner::new(model, env, &profile).plan().ok()
}

pub fn galaxy_latency(model: &ModelConfig, env: &EdgeEnv, mbps: f64, seq: usize) -> Option<f64> {
    galaxy_report(model, env, mbps, seq, OverlapMode::Tiled).map(|r| r.total_s())
}

pub fn baseline_latency(
    kind: BaselineKind,
    model: &ModelConfig,
    env: &EdgeEnv,
    mbps: f64,
    seq: usize,
) -> Option<f64> {
    baselines::simulate(kind, model, env, NetParams::mbps(mbps), seq)
        .ok()
        .map(|r| r.total_s())
}

/// "1.43x" / "OOM" speedup cell: baseline / galaxy.
pub fn speedup_cell(galaxy_s: Option<f64>, baseline_s: Option<f64>) -> String {
    match (galaxy_s, baseline_s) {
        (Some(g), Some(b)) => format!("{:.2}x", b / g),
        (Some(_), None) => "OOM".into(),
        (None, _) => "OOM*".into(), // galaxy itself infeasible
    }
}

/// Wall-clock a closure `n` times, returning (mean_s, min_s).
pub fn time_n(n: usize, mut f: impl FnMut()) -> (f64, f64) {
    let mut total = 0.0;
    let mut best = f64::INFINITY;
    for _ in 0..n {
        let t0 = std::time::Instant::now();
        f();
        let dt = t0.elapsed().as_secs_f64();
        total += dt;
        best = best.min(dt);
    }
    (total / n as f64, best)
}
